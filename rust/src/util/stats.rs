//! Streaming and batch statistics used by the metrics layer and the
//! bench harness: mean/variance (Welford), percentiles, confidence
//! intervals, and a fixed-bin latency histogram.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95% CI of the mean (normal approximation).
    pub fn ci95_half_width(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        1.96 * self.std_dev() / (self.n as f64).sqrt()
    }

    /// Raw accumulator state `(n, mean, m2, min, max)` for engine
    /// snapshots.
    pub fn raw(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean, self.m2, self.min, self.max)
    }

    /// Rebuild an accumulator from [`Welford::raw`] output.
    pub fn from_raw(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        Self { n, mean, m2, min, max }
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample with linear interpolation (type-7, the
/// numpy/Excel default). `q` in [0, 100]. Sorts a copy.
pub fn percentile(data: &[f64], q: f64) -> f64 {
    if data.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 100.0);
    let idx = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = idx - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Fixed-bin histogram over [0, upper) with overflow bin; used for
/// latency CDFs in the bench output.
#[derive(Debug, Clone)]
pub struct Histogram {
    bin_width: f64,
    bins: Vec<u64>,
    overflow: u64,
    count: u64,
    sum: f64,
}

impl Histogram {
    pub fn new(upper: f64, n_bins: usize) -> Self {
        assert!(upper > 0.0 && n_bins > 0);
        Self {
            bin_width: upper / n_bins as f64,
            bins: vec![0; n_bins],
            overflow: 0,
            count: 0,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        let idx = (x / self.bin_width) as usize;
        if x < 0.0 {
            // clamp negatives into bin 0 (latencies should never be < 0)
            self.bins[0] += 1;
        } else if idx >= self.bins.len() {
            self.overflow += 1;
        } else {
            self.bins[idx] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.sum / self.count as f64 }
    }

    /// Fraction of samples <= x (bin-resolution approximation).
    pub fn cdf_at(&self, x: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let idx = ((x / self.bin_width) as usize).min(self.bins.len());
        let below: u64 = self.bins[..idx].iter().sum();
        below as f64 / self.count as f64
    }

    /// Approximate quantile by scanning bins. `q` in [0,1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= target {
                return (i as f64 + 0.5) * self.bin_width;
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let data = [1.0, 2.0, 3.0, 4.0, 5.5, -2.0];
        let mut w = Welford::new();
        for &x in &data {
            w.push(x);
        }
        let mean = data.iter().sum::<f64>() / data.len() as f64;
        let var = data.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (data.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), -2.0);
        assert_eq!(w.max(), 5.5);
        assert_eq!(w.count(), 6);
    }

    #[test]
    fn welford_merge_equals_single_pass() {
        let data: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &data {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &data[..37] {
            a.push(x);
        }
        for &x in &data[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentile_interpolation() {
        let data = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&data, 0.0), 1.0);
        assert_eq!(percentile(&data, 100.0), 4.0);
        assert!((percentile(&data, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&data, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element_and_empty() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn histogram_cdf_and_quantile() {
        let mut h = Histogram::new(10.0, 100);
        for i in 0..1000 {
            h.push(i as f64 / 100.0); // uniform over [0, 10)
        }
        assert_eq!(h.count(), 1000);
        assert!((h.cdf_at(5.0) - 0.5).abs() < 0.02);
        assert!((h.quantile(0.95) - 9.5).abs() < 0.2);
        assert!((h.mean() - 4.995).abs() < 0.01);
    }

    #[test]
    fn histogram_overflow() {
        let mut h = Histogram::new(1.0, 10);
        h.push(5.0);
        h.push(0.5);
        assert_eq!(h.count(), 2);
        assert!((h.cdf_at(1.0) - 0.5).abs() < 1e-9);
        assert_eq!(h.quantile(1.0), f64::INFINITY);
    }
}
