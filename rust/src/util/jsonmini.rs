//! Minimal JSON parser (the `serde_json` replacement; serde is not in
//! the offline dependency universe).
//!
//! Parses the subset the repo's own machine-readable outputs use —
//! objects, arrays, strings, numbers, booleans, null — which is the
//! full JSON value grammar minus any streaming/zero-copy ambition.
//! Used by the benchmark-regression gate to read `BENCH_*.json` and
//! `benchmarks/baseline.json`.

/// A parsed JSON value. Object keys keep document order (the files we
/// read are small; no hashing needed).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Nesting guard: the gate's files are flat; anything deeper is a
/// malformed input, not a use case.
const MAX_DEPTH: usize = 64;

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".to_string());
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected '{}' at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        let end = self.pos + word.len();
        if self.bytes.get(self.pos..end) == Some(word.as_bytes()) {
            self.pos = end;
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "bad number".to_string())?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            // surrogate pairs degrade to the
                            // replacement char — no such names exist in
                            // our bench files
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(format!("bad escape '\\{}'", other as char))
                        }
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| "invalid UTF-8 in string".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-2.5e3").unwrap(), Value::Num(-2500.0));
        assert_eq!(
            Value::parse("\"a\\n\\\"b\\\"\"").unwrap(),
            Value::Str("a\n\"b\"".to_string())
        );
        let v = Value::parse("[1, 2, [3]]").unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 3);
        let v = Value::parse("{\"a\": 1, \"b\": {\"c\": [true, null]}}").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_arr().unwrap()[0],
            Value::Bool(true)
        );
        assert_eq!(Value::parse("{}").unwrap(), Value::Obj(Vec::new()));
        assert_eq!(Value::parse("[]").unwrap(), Value::Arr(Vec::new()));
    }

    #[test]
    fn parses_the_bench_output_shape() {
        // the exact shape util::bench::results_to_json emits
        let text = "[\n  {\"name\": \"dess: 10k schedule+pop\", \"iters\": 50, \
                    \"mean_ns\": 123456.7, \"std_ns\": 10.0, \"min_ns\": 1.0, \
                    \"p50_ns\": 2.0, \"p95_ns\": 3.0}\n]\n";
        let v = Value::parse(text).unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("dess: 10k schedule+pop"));
        assert_eq!(rows[0].get("mean_ns").unwrap().as_f64(), Some(123456.7));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1, ]extra",
            "{\"a\" 1}",
            "[1 2]",
            "\"unterminated",
            "{\"a\": }",
            "nul",
            "01a",
            "[1] garbage",
        ] {
            assert!(Value::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn unicode_escapes_decode() {
        assert_eq!(
            Value::parse("\"\\u0041\\u00e9\"").unwrap(),
            Value::Str("Aé".to_string())
        );
    }
}
