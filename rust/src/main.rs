//! `icc6g` — CLI for the 6G EdgeAI ICC reproduction.
//!
//! Subcommands:
//!   fig4       Fig 4: analytic curves + capacities (opt. MC validation)
//!   fig6       Fig 6: SLS satisfaction vs prompt arrival rate (--threads)
//!   fig7       Fig 7: SLS satisfaction vs compute capacity (×A100, --threads)
//!   simulate   One SLS run with explicit parameters / TOML config
//!   scenario   One multi-class / multi-cell / multi-node Scenario-API run
//!              (--snapshot-out/--snapshot-in checkpoint + resume)
//!   sweep      Parallel capacity sweep (seed × rate grid, N threads;
//!              --warm-start forks rate points from one warmed snapshot)
//!   ab         Paired A/B comparison of two scenario configs under
//!              common random numbers (per-seed deltas + 95% CI)
//!   bench-diff Benchmark-regression gate vs benchmarks/baseline.json
//!   serve      Real LLM serving over the PJRT runtime (TCP)
//!   generate   One-shot generation through the AOT artifacts

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{
    capacity_from_curve, min_capacity_from_curve, sweep_arrival_rates_threaded,
    sweep_gpu_capacity_threaded, CurvePoint,
};
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::tandem_mc::empirical_satisfaction;
use icc6g::queueing::{service_capacity, Scheme};
use icc6g::scenario::{
    CellSpec, RoutingPolicy, ScenarioBuilder, ScenarioEngine, ServiceModelKind, WorkloadClass,
};
use icc6g::sim::run_scheme;
use icc6g::util::args::{usage, Args, OptSpec};
use icc6g::util::bench::{cell, Table};
use icc6g::util::perfgate;

fn main() {
    icc6g::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let code = match cmd {
        "theory" | "fig4" => cmd_fig4(&rest),
        "fig6" => cmd_fig6(&rest),
        "fig7" => cmd_fig7(&rest),
        "simulate" => cmd_simulate(&rest),
        "scenario" => cmd_scenario(&rest),
        "sweep" => cmd_sweep(&rest),
        "ab" => cmd_ab(&rest),
        "bench-diff" => cmd_bench_diff(&rest),
        "serve" => cmd_serve(&rest),
        "generate" => cmd_generate(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "icc6g — 6G EdgeAI ICC reproduction\n\n\
         Usage: icc6g <command> [options]\n\n\
         Commands:\n\
           fig4       analytic Fig 4 curves + service capacities (+MC check)\n\
           fig6       SLS Fig 6: satisfaction vs prompt arrival rate\n\
           fig7       SLS Fig 7: satisfaction vs compute capacity (xA100)\n\
           simulate   one SLS run (--scheme icc|disjoint_ran|mec ...)\n\
           scenario   one Scenario-API run (multi-class, multi-cell, multi-node;\n\
                      --cells N shards the population over N gNBs, --threads\n\
                      steps them in parallel; --isd/--layout place the sites and\n\
                      couple the radios (dynamic inter-cell interference),\n\
                      --speed moves the UEs, --handover enables A3 migration;\n\
                      [[cell]]/[topology]/[mobility]/[handover] in --config;\n\
                      --snapshot-out checkpoints mid-run state to a file and\n\
                      --snapshot-in resumes one, bit-identical to an\n\
                      uninterrupted run)\n\
           sweep      parallel capacity sweep over a rate grid (--threads;\n\
                      --warm-start S simulates each seed's warm-up once,\n\
                      snapshots at S seconds, and forks every rate point\n\
                      from the shared checkpoint)\n\
           ab         paired A/B of two scenario TOMLs under common random\n\
                      numbers: per-seed satisfaction deltas with a 95% CI\n\
           bench-diff benchmark-regression gate: BENCH_*.json vs baseline\n\
           serve      real LLM serving over PJRT (--port, --artifacts)\n\
           generate   one-shot generation via the AOT artifacts\n\
           help       this message\n\n\
         Run a command with --help for its options."
    );
}

fn cmd_fig4(argv: &[String]) -> i32 {
    let specs = [
        OptSpec { name: "alpha", help: "target satisfaction", takes_value: true, default: Some("0.95") },
        OptSpec { name: "mc", help: "validate with Monte-Carlo tandem sim", takes_value: false, default: None },
        OptSpec { name: "points", help: "number of λ grid points", takes_value: true, default: Some("25") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!("{}", usage("icc6g fig4", "Fig 4: theoretical job-satisfaction curves", &specs));
        return 0;
    }
    let alpha = args.get_f64("alpha").unwrap().unwrap();
    let npts = args.get_usize("points").unwrap().unwrap().max(2);
    let p = SystemParams::paper();
    let schemes = Scheme::fig4_schemes();

    let mut t = Table::new(
        "Fig 4 — job satisfaction vs arrival rate (μ1=900, μ2=100, b=80ms)",
        &["lambda", schemes[0].name, schemes[1].name, schemes[2].name],
    );
    for i in 0..npts {
        let lambda = 2.0 + (p.stability_limit() - 4.0) * i as f64 / (npts - 1) as f64;
        let row: Vec<String> = std::iter::once(cell(lambda, 1))
            .chain(schemes.iter().map(|s| cell(scheme_satisfaction(&p, s, lambda), 4)))
            .collect();
        t.row(&row);
    }
    t.print();
    let _ = t.write_csv("fig4_curves.csv");

    let mut caps = Table::new(
        &format!("Fig 4 — service capacity at α = {alpha} (paper: joint-RAN +98% vs MEC)"),
        &["scheme", "capacity (jobs/s)", "vs MEC"],
    );
    let cap = |s: &Scheme| {
        service_capacity(
            |l| scheme_satisfaction(&p, s, l),
            alpha,
            p.stability_limit() - 1e-6,
            1e-6,
        )
        .lambda_star
    };
    let values: Vec<f64> = schemes.iter().map(cap).collect();
    let mec = values[2];
    for (s, v) in schemes.iter().zip(&values) {
        caps.row(&[s.name.to_string(), cell(*v, 2), format!("{:+.1}%", (v / mec - 1.0) * 100.0)]);
    }
    caps.print();
    let _ = caps.write_csv("fig4_capacity.csv");

    if args.flag("mc") {
        let mut mc = Table::new(
            "Fig 4 — Monte-Carlo validation (60k jobs/point)",
            &["lambda", "scheme", "analytic", "simulated", "abs_delta"],
        );
        for &lambda in &[20.0, 40.0, 60.0, 80.0] {
            for s in &schemes {
                let ana = scheme_satisfaction(&p, s, lambda);
                let emp = empirical_satisfaction(&p, s, lambda, 60_000, 42);
                mc.row(&[
                    cell(lambda, 0),
                    s.name.to_string(),
                    cell(ana, 4),
                    cell(emp, 4),
                    cell((ana - emp).abs(), 4),
                ]);
            }
        }
        mc.print();
        let _ = mc.write_csv("fig4_mc.csv");
    }
    0
}

/// Read + parse a TOML config file; the caller prints the error and
/// exits 2.
fn load_toml(path: &str) -> Result<icc6g::util::tomlmini::Document, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    icc6g::util::tomlmini::Document::parse(&text).map_err(|e| e.to_string())
}

fn common_sim_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "seed", help: "master RNG seed", takes_value: true, default: Some("1") },
        OptSpec { name: "horizon", help: "simulated seconds", takes_value: true, default: Some("20") },
        OptSpec { name: "seeds", help: "independent replications", takes_value: true, default: Some("3") },
        OptSpec { name: "alpha", help: "target satisfaction", takes_value: true, default: Some("0.95") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ]
}

fn parse_sim_base(args: &Args) -> SimConfig {
    let mut cfg = SimConfig::table1();
    cfg.seed = args.get_u64("seed").unwrap().unwrap();
    cfg.horizon = args.get_f64("horizon").unwrap().unwrap();
    cfg
}

fn cmd_fig6(argv: &[String]) -> i32 {
    let mut specs = common_sim_specs();
    specs.push(OptSpec {
        name: "threads",
        help: "worker threads for the sweep (0 = all cores)",
        takes_value: true,
        default: Some("1"),
    });
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!("{}", usage("icc6g fig6", "Fig 6: SLS satisfaction vs arrival rate", &specs));
        return 0;
    }
    let base = parse_sim_base(&args);
    let seeds = args.get_u64("seeds").unwrap().unwrap() as u32;
    let alpha = args.get_f64("alpha").unwrap().unwrap();
    let threads = args.get_u64("threads").unwrap().unwrap() as usize;
    let rates: Vec<f64> = (1..=12).map(|i| 10.0 * i as f64).collect();
    let schemes = SchemeConfig::select("all").unwrap();

    let mut t = Table::new(
        "Fig 6 — SLS job satisfaction + avg latencies vs prompt arrival rate",
        &["rate", "scheme", "satisfaction", "avg_comm_ms", "avg_comp_ms"],
    );
    let mut caps = Vec::new();
    for scheme in &schemes {
        let pts = sweep_arrival_rates_threaded(&base, scheme, &rates, seeds, threads);
        for p in &pts {
            t.row(&[
                cell(p.x, 0),
                scheme.name.clone(),
                cell(p.satisfaction, 4),
                cell(p.avg_comm_ms, 2),
                cell(p.avg_comp_ms, 2),
            ]);
        }
        caps.push((scheme.name.clone(), capacity_from_curve(&pts, alpha)));
    }
    t.print();
    let _ = t.write_csv("fig6_curves.csv");

    let mut c = Table::new(
        &format!("Fig 6 — service capacity at α = {alpha} (paper: ICC 80, MEC 50, +60%)"),
        &["scheme", "capacity (prompts/s)", "vs MEC"],
    );
    let mec = caps.last().unwrap().1;
    for (name, v) in &caps {
        c.row(&[name.to_string(), cell(*v, 1), format!("{:+.1}%", (v / mec - 1.0) * 100.0)]);
    }
    c.print();
    let _ = c.write_csv("fig6_capacity.csv");
    0
}

fn cmd_fig7(argv: &[String]) -> i32 {
    let mut specs = common_sim_specs();
    specs.push(OptSpec {
        name: "threads",
        help: "worker threads for the sweep (0 = all cores)",
        takes_value: true,
        default: Some("1"),
    });
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!("{}", usage("icc6g fig7", "Fig 7: SLS satisfaction vs compute capacity", &specs));
        return 0;
    }
    let mut base = parse_sim_base(&args);
    base.n_ues = 60; // paper: 60 UEs × 1 prompt/s
    let seeds = args.get_u64("seeds").unwrap().unwrap() as u32;
    let alpha = args.get_f64("alpha").unwrap().unwrap();
    let threads = args.get_u64("threads").unwrap().unwrap() as usize;
    let capacities: Vec<f64> = (4..=16).map(|i| i as f64).collect();
    let schemes = SchemeConfig::select("all").unwrap();

    let mut t = Table::new(
        "Fig 7 — SLS satisfaction + tokens/s vs compute capacity (×A100), 60 UEs",
        &["xA100", "scheme", "satisfaction", "avg_tokens_per_s"],
    );
    let mut mins = Vec::new();
    for scheme in &schemes {
        let pts = sweep_gpu_capacity_threaded(&base, scheme, &capacities, seeds, threads);
        for p in &pts {
            t.row(&[
                cell(p.x, 0),
                scheme.name.clone(),
                cell(p.satisfaction, 4),
                cell(p.avg_tokens_per_sec, 1),
            ]);
        }
        mins.push((scheme.name.clone(), min_capacity_from_curve(&pts, alpha)));
    }
    t.print();
    let _ = t.write_csv("fig7_curves.csv");

    let mut c = Table::new(
        &format!("Fig 7 — min compute for α = {alpha} (paper: ICC 8 vs disjoint-RAN 11, −27%)"),
        &["scheme", "min xA100"],
    );
    for (name, v) in &mins {
        c.row(&[
            name.to_string(),
            v.map(|x| cell(x, 1)).unwrap_or_else(|| "not reached".into()),
        ]);
    }
    c.print();
    let _ = c.write_csv("fig7_capacity.csv");
    0
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let mut specs = common_sim_specs();
    specs.extend([
        OptSpec { name: "scheme", help: "icc | disjoint_ran | mec", takes_value: true, default: Some("icc") },
        OptSpec { name: "ues", help: "number of UEs", takes_value: true, default: Some("60") },
        OptSpec { name: "config", help: "TOML config file", takes_value: true, default: None },
    ]);
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!("{}", usage("icc6g simulate", "One SLS run", &specs));
        return 0;
    }
    let mut cfg = parse_sim_base(&args);
    cfg.n_ues = args.get_u64("ues").unwrap().unwrap() as u32;
    // The CLI preset is the base; a `[scheme]` table in the config
    // file refines or replaces it.
    let scheme = match SchemeConfig::preset(args.get("scheme").unwrap()) {
        Some(s) => s,
        None => {
            eprintln!("unknown scheme '{}'", args.get("scheme").unwrap());
            return 2;
        }
    };
    cfg = cfg.with_scheme(scheme);
    if let Some(path) = args.get("config") {
        let doc = match load_toml(path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        if let Err(e) = cfg.apply_toml(&doc) {
            eprintln!("{e}");
            return 2;
        }
    }
    let seed = cfg.seed;
    let scheme_name = cfg.scheme.name.clone();
    let report = run_scheme(&cfg, cfg.scheme.clone(), seed);
    println!("scheme       : {scheme_name}");
    println!("offered rate : {:.1} prompts/s", cfg.offered_rate());
    println!("jobs         : {} ({} dropped)", report.n_jobs, report.n_dropped);
    println!("satisfaction : {:.4}", report.satisfaction_rate());
    println!("avg comm     : {:.2} ms", report.comm.mean() * 1e3);
    println!("avg comp     : {:.2} ms", report.comp.mean() * 1e3);
    println!("avg e2e      : {:.2} ms", report.e2e.mean() * 1e3);
    println!("avg tokens/s : {:.1}", report.tokens_per_sec.mean());
    0
}

fn cmd_scenario(argv: &[String]) -> i32 {
    let specs = [
        OptSpec { name: "config", help: "scenario TOML file ([[workload]]/[[node]]/[[cell]] tables)", takes_value: true, default: None },
        OptSpec { name: "scheme", help: "icc | disjoint_ran | mec", takes_value: true, default: Some("icc") },
        OptSpec { name: "ues", help: "number of UEs (total, split across --cells)", takes_value: true, default: Some("20") },
        OptSpec { name: "cells", help: "gNB cells sharing the compute tier (UEs split evenly)", takes_value: true, default: Some("1") },
        OptSpec { name: "threads", help: "worker threads stepping cells (0 = all cores; never changes results)", takes_value: true, default: Some("1") },
        OptSpec { name: "nodes", help: "compute nodes (demo mix)", takes_value: true, default: Some("2") },
        OptSpec { name: "routing", help: "least_loaded | rr | affinity | cell_affinity", takes_value: true, default: Some("least_loaded") },
        OptSpec { name: "service", help: "roofline | token_sampled", takes_value: true, default: Some("token_sampled") },
        OptSpec { name: "isd", help: "inter-site distance in meters; > 0 couples the cell radios (geometry-driven interference replaces the fixed margin)", takes_value: true, default: Some("0") },
        OptSpec { name: "layout", help: "site layout with --isd: hex | linear", takes_value: true, default: Some("hex") },
        OptSpec { name: "speed", help: "UE speed in m/s with --isd (fixed-velocity motion; 0 = static)", takes_value: true, default: Some("0") },
        OptSpec { name: "handover", help: "enable A3 handover between coupled cells (3 dB / 160 ms defaults; tune via [handover] in --config)", takes_value: false, default: None },
        OptSpec { name: "fluid-rings", help: "hybrid fidelity with --isd > 0: keep per-UE simulation within this many rings of the focus cells (default focus: cell 0) and run every farther cell as a fluid mean-field source; tune via [fluid] in --config", takes_value: true, default: None },
        OptSpec { name: "autoscale", help: "elastic control plane policy: fixed | queue_depth | ttft_slo (tune via [cluster] in --config)", takes_value: true, default: None },
        OptSpec { name: "churn", help: "per-node failure process MTBF:MTTR[:SPINUP] in seconds, applied to every demo node (implies --autoscale fixed)", takes_value: true, default: None },
        OptSpec { name: "horizon", help: "simulated seconds", takes_value: true, default: Some("12") },
        OptSpec { name: "seed", help: "master RNG seed", takes_value: true, default: Some("1") },
        OptSpec { name: "json", help: "write the full report (incl. per-class TTFT/TPOT percentiles) to this JSON file", takes_value: true, default: None },
        OptSpec { name: "snapshot-out", help: "checkpoint the engine state to this file at --snapshot-time, then keep running to the horizon", takes_value: true, default: None },
        OptSpec { name: "snapshot-time", help: "capture instant for --snapshot-out in simulated seconds (default: half the horizon)", takes_value: true, default: None },
        OptSpec { name: "snapshot-in", help: "resume from a checkpoint file instead of t = 0 (the CLI scenario options must rebuild the snapshotted config, arrival rates excepted)", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "icc6g scenario",
                "One Scenario-API run: composable workloads on a multi-node tier",
                &specs
            )
        );
        return 0;
    }
    let scheme = match SchemeConfig::preset(args.get("scheme").unwrap()) {
        Some(s) => s,
        None => {
            eprintln!("unknown scheme '{}'", args.get("scheme").unwrap());
            return 2;
        }
    };
    let Some(routing) = RoutingPolicy::parse(args.get("routing").unwrap()) else {
        eprintln!("unknown routing policy '{}'", args.get("routing").unwrap());
        return 2;
    };
    let Some(service) = ServiceModelKind::parse(args.get("service").unwrap()) else {
        eprintln!("unknown service model '{}'", args.get("service").unwrap());
        return 2;
    };
    let (ues, seed, n_nodes, horizon, n_cells, threads) = match (
        args.get_u64("ues"),
        args.get_u64("seed"),
        args.get_u64("nodes"),
        args.get_f64("horizon"),
        args.get_u64("cells"),
        args.get_u64("threads"),
    ) {
        (Ok(u), Ok(s), Ok(n), Ok(h), Ok(c), Ok(t)) => {
            (u.unwrap(), s.unwrap(), n.unwrap(), h.unwrap(), c.unwrap(), t.unwrap())
        }
        (Err(e), ..)
        | (_, Err(e), ..)
        | (_, _, Err(e), ..)
        | (_, _, _, Err(e), ..)
        | (_, _, _, _, Err(e), _)
        | (_, _, _, _, _, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !(1..=1_000_000).contains(&ues) {
        eprintln!("--ues must be in 1..=1000000");
        return 2;
    }
    if horizon <= 0.0 {
        eprintln!("--horizon must be positive");
        return 2;
    }
    if !(1..=1024).contains(&n_nodes) {
        eprintln!("--nodes must be in 1..=1024");
        return 2;
    }
    if !(1..=4096).contains(&n_cells) || n_cells > ues {
        eprintln!("--cells must be in 1..=4096 and <= --ues");
        return 2;
    }
    if threads > 1024 {
        eprintln!("--threads must be in 0..=1024");
        return 2;
    }
    let (isd, speed) = match (args.get_f64("isd"), args.get_f64("speed")) {
        (Ok(i), Ok(s)) => (i.unwrap(), s.unwrap()),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if isd < 0.0 || speed < 0.0 {
        eprintln!("--isd and --speed must be >= 0");
        return 2;
    }
    let layout = match icc6g::scenario::SiteLayout::parse(args.get("layout").unwrap()) {
        Some(l) => l,
        None => {
            eprintln!("unknown layout '{}' (hex | linear)", args.get("layout").unwrap());
            return 2;
        }
    };
    let fluid_rings = match args.get_u64("fluid-rings") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if isd == 0.0 && (speed > 0.0 || args.flag("handover") || fluid_rings.is_some()) {
        eprintln!("--speed/--handover/--fluid-rings require --isd > 0 (a site topology)");
        return 2;
    }
    if fluid_rings.is_some_and(|r| r > 64) {
        eprintln!("--fluid-rings must be in 0..=64");
        return 2;
    }
    let autoscale = match args.get("autoscale") {
        Some(s) => match icc6g::scenario::AutoscalerKind::parse(s) {
            Some(k) => Some(k),
            None => {
                eprintln!("unknown autoscale policy '{s}' (fixed | queue_depth | ttft_slo)");
                return 2;
            }
        },
        None => None,
    };
    let churn = match args.get("churn") {
        Some(spec) => match parse_churn(spec) {
            Ok(c) => Some(c),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => None,
    };
    // Built-in demo mix: 3 classes over N identical nodes, population
    // split evenly over the cells. A config file's
    // [[workload]]/[[node]]/[[cell]] tables replace these defaults.
    let mut b = ScenarioBuilder::new()
        .scheme(scheme)
        .n_ues(ues as u32)
        .horizon(horizon)
        .seed(seed)
        .routing(routing)
        .service_kind(service)
        .threads(threads as usize)
        .workload(WorkloadClass::translation())
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::summarization());
    if n_cells > 1 {
        let (per, rem) = (ues / n_cells, ues % n_cells);
        for i in 0..n_cells {
            b = b.cell(CellSpec::new((per + u64::from(i < rem)) as u32));
        }
    }
    if isd > 0.0 {
        b = b.topology(icc6g::scenario::TopologySpec { layout, isd_m: isd });
        if speed > 0.0 {
            b = b.mobility(icc6g::scenario::MobilitySpec::fixed(speed));
        }
        if args.flag("handover") {
            b = b.handover(icc6g::scenario::HandoverSpec::default());
        }
        if let Some(r) = fluid_rings {
            b = b.fluid(icc6g::scenario::FluidSpec {
                rings: r as u32,
                ..Default::default()
            });
        }
    }
    for _ in 0..n_nodes {
        b = b.node(icc6g::llm::GpuSpec::gh200_nvl2().scaled(2.0), 1);
        if let Some(c) = churn {
            b = b.node_churn(c);
        }
    }
    if autoscale.is_some() || churn.is_some() {
        b = b.cluster(icc6g::scenario::ClusterSpec {
            policy: autoscale.unwrap_or(icc6g::scenario::AutoscalerKind::Fixed),
            ..Default::default()
        });
    }
    if let Some(path) = args.get("config") {
        let doc = match load_toml(path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        b = match b.apply_toml(&doc) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    }
    let scenario = match b.try_build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("invalid scenario: {e}");
            return 2;
        }
    };
    let res = if let Some(inp) = args.get("snapshot-in") {
        let blob = match std::fs::read(inp) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("cannot read snapshot '{inp}': {e}");
                return 1;
            }
        };
        let mut eng = match ScenarioEngine::from_snapshot(&scenario, &blob) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("cannot restore snapshot '{inp}': {e}");
                return 2;
            }
        };
        eprintln!("resumed from '{inp}' at t = {:.3} s", eng.now());
        eng.run_to(f64::INFINITY);
        eng.finish()
    } else if let Some(outp) = args.get("snapshot-out") {
        let t_snap = match args.get_f64("snapshot-time") {
            Ok(t) => t.unwrap_or(horizon * 0.5),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        if !(0.0..=horizon).contains(&t_snap) {
            eprintln!("--snapshot-time must be in 0..=horizon");
            return 2;
        }
        let mut eng = ScenarioEngine::new(&scenario);
        eng.run_to(t_snap);
        let blob = eng.snapshot();
        if let Err(e) = std::fs::write(outp, &blob) {
            eprintln!("cannot write snapshot '{outp}': {e}");
            return 1;
        }
        eprintln!("wrote {} byte snapshot at t = {t_snap:.3} s to '{outp}'", blob.len());
        eng.run_to(f64::INFINITY);
        eng.finish()
    } else {
        scenario.run()
    };
    println!("scheme       : {}", scenario.scheme().name);
    println!("service      : {}", scenario.service_name());
    println!(
        "cells        : {} ({} UEs total, {} thread(s))",
        scenario.cells().len(),
        scenario.total_ues(),
        icc6g::sweep::resolve_threads(scenario.threads()).min(scenario.cells().len().max(1)),
    );
    if let Some(t) = scenario.topology() {
        let motion = match scenario.mobility() {
            Some(m) => match m.model {
                icc6g::scenario::MobilityModel::FixedVelocity { speed } => {
                    format!(", UEs at {speed:.1} m/s")
                }
                icc6g::scenario::MobilityModel::RandomWaypoint { v_min, v_max } => {
                    format!(", waypoint UEs {v_min:.1}-{v_max:.1} m/s")
                }
            },
            None => ", static UEs".to_string(),
        };
        println!(
            "topology     : {} grid, ISD {:.0} m (coupled radios){motion}{}",
            t.layout.name(),
            t.isd_m,
            if scenario.handover().is_some() { ", A3 handover" } else { "" },
        );
        if let Some(f) = scenario.fluid() {
            let n_fluid =
                (0..scenario.cells().len()).filter(|&k| f.is_fluid(t, k)).count();
            println!(
                "fluid tier   : {} focus cell(s) per-UE, {} far-ring cell(s) fluid (rings = {})",
                scenario.cells().len() - n_fluid,
                n_fluid,
                f.rings,
            );
        }
    }
    println!(
        "routing      : {} over {} node(s)",
        scenario.routing().name(),
        scenario.nodes().len()
    );
    for (i, n) in scenario.nodes().iter().enumerate() {
        let exec = match n.execution {
            icc6g::scenario::ExecutionModel::Sequential => {
                format!("sequential, {} server(s)", n.n_servers)
            }
            icc6g::scenario::ExecutionModel::ContinuousBatching { max_batch, kv_budget } => {
                format!(
                    "continuous batching, max_batch {max_batch}, KV {:.1} GB",
                    kv_budget / 1e9
                )
            }
        };
        println!("  node {i}     : {} ({exec})", n.gpu.display_name());
    }
    println!("offered rate : {:.1} jobs/s", scenario.offered_rate());
    println!("jobs         : {} ({} dropped)", res.report.n_jobs, res.report.n_dropped);
    println!("satisfaction : {:.4}", res.report.satisfaction_rate());
    println!("events       : {}", res.events);
    let mut t = Table::new(
        "per-class breakdown (latencies ms; TTFT/TPOT over completed jobs)",
        &[
            "class",
            "jobs",
            "dropped",
            "satisfaction",
            "avg_comm_ms",
            "avg_e2e_ms",
            "ttft_p50",
            "ttft_p95",
            "ttft_p99",
            "tpot_p50",
            "tpot_p95",
            "tpot_p99",
        ],
    );
    for c in &res.report.per_class {
        let qs = [50.0, 95.0, 99.0];
        let ttft = c.ttft_percentiles(&qs);
        let tpot = c.tpot_percentiles(&qs);
        t.row(&[
            c.name.clone(),
            c.n_jobs.to_string(),
            c.n_dropped.to_string(),
            cell(c.satisfaction_rate(), 4),
            cell(c.comm.mean() * 1e3, 2),
            cell(c.e2e.mean() * 1e3, 2),
            cell(ttft[0] * 1e3, 2),
            cell(ttft[1] * 1e3, 2),
            cell(ttft[2] * 1e3, 2),
            cell(tpot[0] * 1e3, 3),
            cell(tpot[1] * 1e3, 3),
            cell(tpot[2] * 1e3, 3),
        ]);
    }
    t.print();
    let _ = t.write_csv("scenario_classes.csv");
    if !res.report.per_model.is_empty() {
        let mut mt = Table::new(
            "per-model breakdown (zoo runs; jobs judged by their class budgets)",
            &[
                "model",
                "jobs",
                "dropped",
                "satisfaction",
                "avg_comp_ms",
                "avg_e2e_ms",
                "avg_tok_per_s",
                "ttft_p95",
            ],
        );
        for c in &res.report.per_model {
            mt.row(&[
                c.name.clone(),
                c.n_jobs.to_string(),
                c.n_dropped.to_string(),
                cell(c.satisfaction_rate(), 4),
                cell(c.comp.mean() * 1e3, 2),
                cell(c.e2e.mean() * 1e3, 2),
                cell(c.tokens_per_sec.mean(), 1),
                cell(c.ttft_percentile(95.0) * 1e3, 2),
            ]);
        }
        mt.print();
        let _ = mt.write_csv("scenario_models.csv");
    }
    if res.report.per_cell.len() > 1 {
        let mut ct = Table::new(
            "per-cell breakdown (originating gNB; jobs judged by their class budgets)",
            &["cell", "ues", "jobs", "dropped", "satisfaction", "avg_comm_ms", "avg_e2e_ms"],
        );
        for (c, spec) in res.report.per_cell.iter().zip(scenario.cells()) {
            ct.row(&[
                c.name.clone(),
                spec.n_ues.to_string(),
                c.n_jobs.to_string(),
                c.n_dropped.to_string(),
                cell(c.satisfaction_rate(), 4),
                cell(c.comm.mean() * 1e3, 2),
                cell(c.e2e.mean() * 1e3, 2),
            ]);
        }
        ct.print();
        let _ = ct.write_csv("scenario_cells.csv");
    }
    if let Some(fl) = &res.fluid {
        let mut ft = Table::new(
            "fluid tier (far-ring cells: mean-field activity + Eq 3-6 closed forms)",
            &["class", "lambda_per_cell", "mean_sojourn_ms", "satisfaction"],
        );
        for c in &fl.classes {
            ft.row(&[
                c.name.clone(),
                cell(c.lambda_per_cell, 2),
                c.mean_sojourn.map(|s| cell(s * 1e3, 2)).unwrap_or_else(|| "unstable".into()),
                cell(c.satisfaction, 4),
            ]);
        }
        ft.print();
        let _ = ft.write_csv("scenario_fluid.csv");
        let mean_act = if fl.cells.is_empty() {
            0.0
        } else {
            fl.cells.iter().map(|c| c.mean_activity).sum::<f64>() / fl.cells.len() as f64
        };
        println!(
            "fluid load   : mean activity {mean_act:.3} over {} cell(s), background rho {:.3}/node",
            fl.cells.len(),
            fl.node_rho,
        );
    }
    if !res.report.radio.is_empty() {
        let mut rt = Table::new(
            "per-cell radio (coupled cells: A3 handovers + applied interference-over-thermal)",
            &["cell", "ho_in", "ho_out", "avg_iot_db", "max_iot_db"],
        );
        for (k, r) in res.report.radio.iter().enumerate() {
            rt.row(&[
                format!("cell{k}"),
                r.handovers_in.to_string(),
                r.handovers_out.to_string(),
                cell(r.iot_db.mean(), 2),
                cell(r.iot_db.max(), 2),
            ]);
        }
        rt.print();
        let _ = rt.write_csv("scenario_radio.csv");
    }
    if !res.report.cluster.is_empty() {
        let cl = &res.report.cluster;
        let mut nt = Table::new(
            "per-node cluster accounting (powered time priced from the GPU catalog)",
            &["node", "gpu", "up_s", "gpu_s", "kJ", "usd", "served", "redisp", "lost", "fails"],
        );
        for n in &cl.nodes {
            nt.row(&[
                n.name.clone(),
                n.gpu.clone(),
                cell(n.up_seconds, 1),
                cell(n.gpu_seconds, 1),
                cell(n.joules / 1e3, 2),
                cell(n.dollars, 4),
                n.served.to_string(),
                n.redispatched.to_string(),
                n.lost.to_string(),
                n.failures.to_string(),
            ]);
        }
        nt.print();
        let _ = nt.write_csv("scenario_cluster.csv");
        let policy = scenario.cluster().map_or("fixed", |s| s.policy.name());
        println!(
            "cluster      : {policy} policy, {} re-dispatched, {} lost, {} node failure(s)",
            cl.nodes.iter().map(|n| n.redispatched).sum::<u64>(),
            res.report.n_lost,
            cl.nodes.iter().map(|n| n.failures).sum::<u64>(),
        );
        println!(
            "tier cost    : {:.1} GPU-s, {:.1} kJ, ${:.4} — {:.1} satisfied jobs per dollar",
            cl.nodes.iter().map(|n| n.gpu_seconds).sum::<f64>(),
            cl.total_joules() / 1e3,
            cl.total_dollars(),
            cl.capacity_per_dollar(res.report.n_satisfied),
        );
    }
    if let Some(path) = args.get("json") {
        if let Err(e) = std::fs::write(path, res.report.to_json()) {
            eprintln!("cannot write {path}: {e}");
            return 1;
        }
        println!("report       : {path}");
    }
    0
}

/// Parse a `min:max:points` linspace spec (e.g. `10:120:12`).
fn parse_grid(spec: &str) -> Result<Vec<f64>, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    let [lo, hi, n] = parts.as_slice() else {
        return Err(format!("bad grid '{spec}': expected min:max:points"));
    };
    let lo: f64 = lo.parse().map_err(|_| format!("bad grid min '{lo}'"))?;
    let hi: f64 = hi.parse().map_err(|_| format!("bad grid max '{hi}'"))?;
    let n: usize = n.parse().map_err(|_| format!("bad grid points '{n}'"))?;
    if !(lo.is_finite() && hi.is_finite()) || lo <= 0.0 || hi < lo || n < 1 {
        return Err(format!("bad grid '{spec}': need 0 < min <= max, points >= 1"));
    }
    if n == 1 {
        return Ok(vec![lo]);
    }
    Ok((0..n).map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64).collect())
}

/// Parse a `--churn MTBF:MTTR[:SPINUP]` spec (seconds).
fn parse_churn(spec: &str) -> Result<icc6g::scenario::NodeChurnSpec, String> {
    let parts: Vec<&str> = spec.split(':').collect();
    let (mtbf, mttr, spin) = match parts.as_slice() {
        [a, b] => (*a, *b, None),
        [a, b, c] => (*a, *b, Some(*c)),
        _ => return Err(format!("bad churn '{spec}': expected MTBF:MTTR[:SPINUP]")),
    };
    let num = |name: &str, s: &str| -> Result<f64, String> {
        s.parse::<f64>().map_err(|_| format!("bad churn {name} '{s}'"))
    };
    let churn = icc6g::scenario::NodeChurnSpec {
        mtbf: num("mtbf", mtbf)?,
        mttr: num("mttr", mttr)?,
        spinup: match spin {
            Some(s) => num("spinup", s)?,
            None => icc6g::scenario::NodeChurnSpec::default().spinup,
        },
    };
    let ok = churn.mtbf > 0.0
        && churn.mttr > 0.0
        && churn.mttr.is_finite()
        && churn.spinup >= 0.0
        && churn.spinup.is_finite();
    if !ok {
        return Err(format!("bad churn '{spec}': need mtbf > 0, finite mttr > 0, finite spinup >= 0"));
    }
    Ok(churn)
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let specs = [
        OptSpec { name: "scheme", help: "icc | disjoint_ran | mec | all", takes_value: true, default: Some("all") },
        OptSpec { name: "rates", help: "arrival-rate grid min:max:points (prompts/s)", takes_value: true, default: Some("10:120:12") },
        OptSpec { name: "seeds", help: "independent replications per point", takes_value: true, default: Some("3") },
        OptSpec { name: "threads", help: "worker threads (0 = all cores)", takes_value: true, default: Some("0") },
        OptSpec { name: "seed", help: "master RNG seed", takes_value: true, default: Some("1") },
        OptSpec { name: "horizon", help: "simulated seconds per replication", takes_value: true, default: Some("20") },
        OptSpec { name: "alpha", help: "target satisfaction", takes_value: true, default: Some("0.95") },
        OptSpec { name: "warm-start", help: "warm-up seconds to share per seed: simulate once, checkpoint, fork across the rate axis. Holds the UE population fixed and scales the per-UE rate (the cold sweep grows the population), so curves differ slightly from a cold sweep at the same grid", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "icc6g sweep",
                "Capacity sweep over a (rate × seed) grid on worker threads.\n\
                 Replications are independent and merge in seed order, so the\n\
                 thread count never changes the numbers — only the wall clock.",
                &specs
            )
        );
        return 0;
    }
    let rates = match parse_grid(args.get("rates").unwrap()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut base = parse_sim_base(&args);
    // Short probe horizons must still leave a measured window.
    base.warmup = base.warmup.min(base.horizon * 0.25);
    let seeds = args.get_u64("seeds").unwrap().unwrap().clamp(1, 10_000) as u32;
    let threads = args.get_u64("threads").unwrap().unwrap() as usize;
    let alpha = args.get_f64("alpha").unwrap().unwrap();
    let schemes: Vec<SchemeConfig> = match SchemeConfig::select(args.get("scheme").unwrap()) {
        Some(s) => s,
        None => {
            eprintln!(
                "unknown scheme '{}' (icc | disjoint_ran | mec | all)",
                args.get("scheme").unwrap()
            );
            return 2;
        }
    };

    let warm_s = match args.get_f64("warm-start") {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let Some(w) = warm_s {
        if !(0.0..base.horizon).contains(&w) {
            eprintln!("--warm-start must be in 0..horizon");
            return 2;
        }
    }

    let n_workers = icc6g::sweep::resolve_threads(threads);
    let n_runs = rates.len() * seeds as usize * schemes.len();
    println!(
        "sweep: {} rate point(s) × {seeds} seed(s) × {} scheme(s) = {n_runs} runs on {n_workers} thread(s)",
        rates.len(),
        schemes.len(),
    );
    if let Some(w) = warm_s {
        println!(
            "warm-start: sharing one {w:.1} s warm-up per (scheme, seed) across {} rate point(s)",
            rates.len(),
        );
    }
    let wall0 = std::time::Instant::now();
    let mut t = Table::new(
        "Sweep — SLS job satisfaction + avg latencies vs prompt arrival rate",
        &["rate", "scheme", "satisfaction", "avg_comm_ms", "avg_comp_ms"],
    );
    let mut caps = Vec::new();
    for scheme in &schemes {
        let pts = match warm_s {
            Some(w) => {
                // Warm-started points fix the UE population and scale
                // the per-UE rate: snapshot forking requires every grid
                // point to share the cell/UE structure, which the cold
                // sweep's population scaling breaks. The warm-up
                // transient runs at the first grid rate (documented
                // approximation — WarmStart::Forced).
                let seed_list = icc6g::sweep::replication_seeds(base.seed, seeds);
                icc6g::sweep::sweep_grid_warm(
                    &rates,
                    &seed_list,
                    w,
                    threads,
                    icc6g::sweep::WarmStart::Forced,
                    |x, seed| {
                        let mut cfg = base.clone().with_scheme(scheme.clone());
                        cfg.seed = seed;
                        cfg.job_traffic.rate_per_ue = x / cfg.n_ues as f64;
                        ScenarioBuilder::from_sim_config(&cfg).build()
                    },
                )
                .into_iter()
                .map(|p| CurvePoint::from_report(p.x, &p.report))
                .collect()
            }
            None => sweep_arrival_rates_threaded(&base, scheme, &rates, seeds, threads),
        };
        for p in &pts {
            t.row(&[
                cell(p.x, 1),
                scheme.name.clone(),
                cell(p.satisfaction, 4),
                cell(p.avg_comm_ms, 2),
                cell(p.avg_comp_ms, 2),
            ]);
        }
        caps.push((scheme.name.clone(), capacity_from_curve(&pts, alpha)));
    }
    let wall = wall0.elapsed().as_secs_f64();
    t.print();
    let _ = t.write_csv("sweep_curves.csv");

    let mut c = Table::new(
        &format!("Sweep — service capacity at α = {alpha}"),
        &["scheme", "capacity (prompts/s)"],
    );
    for (name, v) in &caps {
        c.row(&[name.to_string(), cell(*v, 1)]);
    }
    c.print();
    let _ = c.write_csv("sweep_capacity.csv");
    println!(
        "\n{n_runs} replications in {wall:.2} s wall ({:.2} runs/s on {n_workers} thread(s))",
        n_runs as f64 / wall.max(1e-9),
    );
    0
}

fn cmd_ab(argv: &[String]) -> i32 {
    let specs = [
        OptSpec { name: "seeds", help: "paired replications (one shared seed per pair)", takes_value: true, default: Some("5") },
        OptSpec { name: "seed", help: "master RNG seed (replication s uses seed + 1000·s on both sides)", takes_value: true, default: Some("1") },
        OptSpec { name: "threads", help: "worker threads (0 = all cores)", takes_value: true, default: Some("0") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") || args.positional().len() != 2 {
        print!(
            "{}",
            usage(
                "icc6g ab <scenario_a.toml> <scenario_b.toml>",
                "Paired A/B comparison of two scenario configs under common\n\
                 random numbers: each replication runs both configs at the\n\
                 same seed, so the per-seed satisfaction deltas cancel the\n\
                 shared simulation noise and the 95% CI on the mean delta is\n\
                 far tighter than an unpaired comparison's.",
                &specs
            )
        );
        return if args.flag("help") { 0 } else { 2 };
    }
    let (path_a, path_b) = (&args.positional()[0], &args.positional()[1]);
    let (seeds, base_seed, threads) = match (
        args.get_u64("seeds"),
        args.get_u64("seed"),
        args.get_u64("threads"),
    ) {
        (Ok(n), Ok(s), Ok(t)) => {
            (n.unwrap().clamp(1, 10_000) as u32, s.unwrap(), t.unwrap() as usize)
        }
        (Err(e), ..) | (_, Err(e), _) | (_, _, Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };

    let docs: Vec<icc6g::util::tomlmini::Document> = match [path_a, path_b]
        .iter()
        .map(|p| load_toml(p))
        .collect::<Result<Vec<_>, _>>()
    {
        Ok(d) => d,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Validate both configs once up front so the parallel replications
    // below can't fail halfway through a run matrix.
    for (doc, path) in docs.iter().zip([path_a, path_b]) {
        if let Err(e) =
            ScenarioBuilder::new().apply_toml(doc).and_then(|b| b.seed(base_seed).try_build())
        {
            eprintln!("invalid scenario '{path}': {e}");
            return 2;
        }
    }
    let metric = |doc: &icc6g::util::tomlmini::Document, seed: u64| -> f64 {
        ScenarioBuilder::new()
            .apply_toml(doc)
            .expect("config validated above")
            .seed(seed)
            .try_build()
            .expect("config validated above")
            .run()
            .report
            .satisfaction_rate()
    };

    let seed_list = icc6g::sweep::replication_seeds(base_seed, seeds);
    println!(
        "ab: {seeds} paired replication(s), A = '{path_a}', B = '{path_b}', CRN on shared seeds"
    );
    let rep = icc6g::sweep::sweep_ab(
        &seed_list,
        threads,
        |s| metric(&docs[0], s),
        |s| metric(&docs[1], s),
    );

    let mut t = Table::new(
        "A/B — per-seed satisfaction under common random numbers",
        &["seed", "sat_a", "sat_b", "delta (b-a)"],
    );
    for i in 0..rep.seeds.len() {
        t.row(&[
            rep.seeds[i].to_string(),
            cell(rep.a[i], 4),
            cell(rep.b[i], 4),
            cell(rep.deltas[i], 4),
        ]);
    }
    t.print();
    let _ = t.write_csv("ab_pairs.csv");
    println!("\nmean satisfaction : A {:.4}, B {:.4}", rep.mean_a, rep.mean_b);
    println!("paired delta      : {:+.4} ± {:.4} (95% CI)", rep.delta_mean, rep.delta_ci95);
    println!(
        "verdict           : {}",
        if rep.significant() {
            if rep.delta_mean > 0.0 { "B better (CI excludes 0)" } else { "A better (CI excludes 0)" }
        } else {
            "no significant difference at 95%"
        }
    );
    0
}

fn cmd_bench_diff(argv: &[String]) -> i32 {
    let specs = [
        OptSpec { name: "baseline", help: "committed baseline JSON", takes_value: true, default: Some("benchmarks/baseline.json") },
        OptSpec { name: "hotpath", help: "BENCH_hotpath.json from `cargo bench --bench perf_hotpath`", takes_value: true, default: Some("BENCH_hotpath.json") },
        OptSpec { name: "scale", help: "BENCH_scale.json from `cargo bench --bench perf_scale`", takes_value: true, default: Some("BENCH_scale.json") },
        OptSpec { name: "tolerance", help: "override the baseline's relative tolerance", takes_value: true, default: None },
        OptSpec { name: "update", help: "rewrite the baseline from the current BENCH files", takes_value: false, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = match Args::parse(argv.iter().cloned(), &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "icc6g bench-diff",
                "Benchmark-regression gate: compare BENCH_*.json against the\n\
                 committed baseline (markdown delta table on stdout; exit 1 on\n\
                 any regression beyond tolerance). --update refreshes the\n\
                 baseline from the current measurements instead.",
                &specs
            )
        );
        return 0;
    }

    // Collect measurements from whichever bench outputs exist.
    let mut measured: Vec<(String, f64)> = Vec::new();
    for (flag, parse) in [
        ("hotpath", perfgate::hotpath_metrics as fn(&str) -> anyhow::Result<Vec<(String, f64)>>),
        ("scale", perfgate::scale_metrics as fn(&str) -> anyhow::Result<Vec<(String, f64)>>),
    ] {
        let path = args.get(flag).unwrap();
        match std::fs::read_to_string(path) {
            Ok(text) => match parse(&text) {
                Ok(mut m) => measured.append(&mut m),
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            },
            Err(e) => eprintln!("note: skipping {path}: {e}"),
        }
    }
    if measured.is_empty() {
        eprintln!("no measurements found — run the perf benches first");
        return 2;
    }

    let baseline_path = args.get("baseline").unwrap();
    if args.flag("update") {
        // Same range rule as the gate path — writing an out-of-range
        // tolerance would produce a baseline parse_baseline rejects.
        let tol = match args.get_f64("tolerance") {
            Ok(Some(t)) if (0.0..1.0).contains(&t) => t,
            Ok(Some(t)) => {
                eprintln!("--tolerance must be in [0, 1), got {t}");
                return 2;
            }
            Ok(None) => 0.25,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        if let Some(dir) = std::path::Path::new(baseline_path).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let text = perfgate::baseline_json(&measured, tol);
        if let Err(e) = std::fs::write(baseline_path, text) {
            eprintln!("cannot write {baseline_path}: {e}");
            return 1;
        }
        println!("refreshed {baseline_path} from {} measurement(s)", measured.len());
        return 0;
    }

    let mut baseline = match std::fs::read_to_string(baseline_path) {
        Ok(text) => match perfgate::parse_baseline(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        Err(e) => {
            eprintln!("cannot read {baseline_path}: {e} (run with --update to create it)");
            return 2;
        }
    };
    match args.get_f64("tolerance") {
        Ok(Some(t)) if (0.0..1.0).contains(&t) => baseline.tolerance = t,
        Ok(Some(t)) => {
            eprintln!("--tolerance must be in [0, 1), got {t}");
            return 2;
        }
        Ok(None) => {}
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }

    let deltas = perfgate::diff(&baseline, &measured);
    let extras: Vec<(String, f64)> = measured
        .iter()
        .filter(|(k, _)| !baseline.entries.iter().any(|e| e.key == *k))
        .cloned()
        .collect();
    print!("{}", perfgate::render_markdown(&deltas, &extras, baseline.tolerance));
    if deltas.iter().any(|d| d.regressed) {
        eprintln!("bench-diff: regression beyond tolerance — failing the gate");
        1
    } else {
        0
    }
}

fn cmd_serve(argv: &[String]) -> i32 {
    match icc6g::server::cli_serve(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_generate(argv: &[String]) -> i32 {
    match icc6g::runtime::cli_generate(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("generate failed: {e:#}");
            1
        }
    }
}
