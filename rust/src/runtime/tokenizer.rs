//! Byte-level tokenizer, mirroring `python/compile/aot.py::byte_tokenize`.
//!
//! Token space: 0..=255 are raw UTF-8 bytes, 256 = BOS, 257 = EOS; the
//! remainder of the 512-token vocabulary is unused padding space. The
//! served model is a from-scratch tiny Llama, so a learned subword
//! vocabulary would add nothing — bytes keep the Rust and Python sides
//! trivially in lock-step (asserted by the golden-trace test).

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const VOCAB: i32 = 512;

/// Encode text into token ids (BOS + raw bytes).
pub fn encode(text: &str) -> Vec<i32> {
    let mut toks = Vec::with_capacity(text.len() + 1);
    toks.push(BOS);
    toks.extend(text.as_bytes().iter().map(|&b| b as i32));
    toks
}

/// Decode token ids back to text; non-byte tokens are dropped, invalid
/// UTF-8 is replaced.
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_prepends_bos() {
        assert_eq!(encode("ab"), vec![BOS, 97, 98]);
    }

    #[test]
    fn roundtrip_ascii() {
        let text = "The 6G network.";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn roundtrip_multibyte_utf8() {
        let text = "héllo wörld — 訳";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn decode_skips_specials() {
        assert_eq!(decode(&[BOS, 104, 105, EOS]), "hi");
    }

    #[test]
    fn all_tokens_in_vocab() {
        for t in encode("any text at all ☃") {
            assert!((0..VOCAB).contains(&t));
        }
    }
}
