//! PJRT inference engine: loads the AOT artifacts (HLO text + weights)
//! and serves prefill/decode from Rust. Python never runs here.
//!
//! Hot-path design:
//! * Both executables are compiled once at load time.
//! * Weights are uploaded to device buffers **once** and passed by
//!   reference to every `execute_b` call (a naive per-call `Literal`
//!   path would memcpy the full 14 MB of parameters on every decode
//!   step — see EXPERIMENTS.md §Perf).
//! * The KV cache round-trips as buffers between steps; only logits
//!   (V floats) are copied to the host per token.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::weights::Weights;
use super::xla;

/// Architecture metadata from `artifacts/model_meta.txt`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub n_params: usize,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = std::collections::BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once(' ')
                .with_context(|| format!("bad meta line '{line}'"))?;
            map.insert(k.to_string(), v.trim().parse::<usize>()?);
        }
        let get = |k: &str| -> Result<usize> {
            map.copied_get(k)
        };
        Ok(Self {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            d_ffn: get("d_ffn")?,
            max_seq: get("max_seq")?,
            n_params: get("n_params")?,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// KV-cache element count ([L, H, S, Dh]).
    pub fn kv_elements(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.head_dim
    }
}

trait MetaMap {
    fn copied_get(&self, k: &str) -> Result<usize>;
}

impl MetaMap for std::collections::BTreeMap<String, usize> {
    fn copied_get(&self, k: &str) -> Result<usize> {
        self.get(k).copied().with_context(|| format!("meta key '{k}' missing"))
    }
}

/// Greedy argmax over a logits slice.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// The KV cache between decode steps (device buffers).
pub struct KvCache {
    k: xla::PjRtBuffer,
    v: xla::PjRtBuffer,
    /// Valid positions (next token writes at `len`).
    pub len: usize,
}

/// Timing counters for one generation (drives the serving metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct GenStats {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_out: usize,
}

impl GenStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_s > 0.0 { self.tokens_out as f64 / self.decode_s } else { 0.0 }
    }
}

/// The loaded engine.
pub struct Engine {
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub meta: ModelMeta,
}

impl Engine {
    /// Load HLO text + weights + metadata from an artifacts directory.
    pub fn load(artifacts: &Path) -> Result<Self> {
        let meta = ModelMeta::load(&artifacts.join("model_meta.txt"))?;
        let weights = Weights::load(&artifacts.join("weights.bin"))?;
        if weights.total_params() != meta.n_params {
            bail!(
                "weights.bin has {} params but meta says {}",
                weights.total_params(),
                meta.n_params
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load_exe = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = artifacts.join(name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {name}"))
        };
        let prefill_exe = load_exe("prefill.hlo.txt")?;
        let decode_exe = load_exe("decode.hlo.txt")?;

        // Upload weights once.
        let mut weight_bufs = Vec::with_capacity(weights.tensors.len());
        for t in &weights.tensors {
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                .with_context(|| format!("uploading weight '{}'", t.name))?;
            weight_bufs.push(buf);
        }
        Ok(Self { client, prefill_exe, decode_exe, weight_bufs, meta })
    }

    /// Artifacts directory from `$ICC6G_ARTIFACTS` or ./artifacts.
    pub fn default_artifacts_dir() -> PathBuf {
        std::env::var("ICC6G_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    /// Upload an f32 host array (used by callers that need custom
    /// inputs, e.g. the batched-decode extension in examples/).
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Split a flat `[logits | k | v]` output (see aot.py's xla-0.5.1
    /// note) into host logits + device KV buffers.
    fn split_flat_output(
        &self,
        flat: Vec<f32>,
        n_logits: usize,
        new_len: usize,
    ) -> Result<(Vec<f32>, KvCache)> {
        let m = &self.meta;
        let kvn = m.kv_elements();
        if flat.len() != n_logits + 2 * kvn {
            bail!(
                "flat output length {} != logits {} + 2×kv {}",
                flat.len(),
                n_logits,
                kvn
            );
        }
        let kv_dims = [m.n_layers, m.n_heads, m.max_seq, m.head_dim];
        let k = self
            .client
            .buffer_from_host_buffer::<f32>(&flat[n_logits..n_logits + kvn], &kv_dims, None)?;
        let v = self.client.buffer_from_host_buffer::<f32>(
            &flat[n_logits + kvn..],
            &kv_dims,
            None,
        )?;
        let mut logits = flat;
        logits.truncate(n_logits);
        Ok((logits, KvCache { k, v, len: new_len }))
    }

    /// Run prefill on a padded prompt. Returns per-position logits
    /// (row-major [max_seq, vocab]) and the KV cache.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, KvCache)> {
        let m = &self.meta;
        if prompt.is_empty() || prompt.len() > m.max_seq {
            bail!("prompt length {} out of range 1..={}", prompt.len(), m.max_seq);
        }
        let mut padded = vec![0i32; m.max_seq];
        padded[..prompt.len()].copy_from_slice(prompt);
        let tok_buf = self.buf_i32(&padded, &[m.max_seq])?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let out = self.prefill_exe.execute_b(&args)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple1()?.to_vec::<f32>()?;
        self.split_flat_output(flat, m.max_seq * m.vocab, prompt.len())
    }

    /// One decode step: feed `token` at position `kv.len`, returning
    /// the next-token logits and the updated cache.
    pub fn decode_step(&self, token: i32, kv: KvCache) -> Result<(Vec<f32>, KvCache)> {
        let m = &self.meta;
        if kv.len >= m.max_seq {
            bail!("KV cache full ({} positions)", m.max_seq);
        }
        let tok_buf = self.buf_i32(&[token], &[1])?;
        let pos_buf = self.buf_i32(&[kv.len as i32], &[1])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&kv.k);
        args.push(&kv.v);
        let out = self.decode_exe.execute_b(&args)?;
        let flat = out[0][0].to_literal_sync()?.to_tuple1()?.to_vec::<f32>()?;
        self.split_flat_output(flat, m.vocab, kv.len + 1)
    }

    /// Greedy generation: prefill the prompt, then decode `n_output`
    /// tokens (or until the cache fills).
    pub fn generate(&self, prompt: &[i32], n_output: usize) -> Result<(Vec<i32>, GenStats)> {
        let mut stats = GenStats::default();
        let t0 = std::time::Instant::now();
        let (logits, mut kv) = self.prefill(prompt)?;
        stats.prefill_s = t0.elapsed().as_secs_f64();

        let v = self.meta.vocab;
        let last = prompt.len() - 1;
        let mut tok = argmax(&logits[last * v..(last + 1) * v]);
        let mut out = Vec::with_capacity(n_output);
        let t1 = std::time::Instant::now();
        for _ in 0..n_output {
            out.push(tok);
            if kv.len >= self.meta.max_seq {
                break;
            }
            let (logits, kv2) = self.decode_step(tok, kv)?;
            kv = kv2;
            tok = argmax(&logits);
        }
        stats.decode_s = t1.elapsed().as_secs_f64();
        stats.tokens_out = out.len();
        Ok((out, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parse_roundtrip() {
        let text = "vocab 512\nd_model 256\nn_layers 4\nn_heads 8\nhead_dim 32\nd_ffn 704\nmax_seq 64\nseed 0\nn_params 3481600\n";
        let m = ModelMeta::parse(text).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.max_seq, 64);
        assert_eq!(m.kv_elements(), 4 * 8 * 64 * 32);
    }

    #[test]
    fn meta_missing_key_rejected() {
        assert!(ModelMeta::parse("vocab 512\n").is_err());
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        // ties resolve to the first maximum (matches jnp.argmax)
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
    }

    // Engine-level tests that need the compiled artifacts live in
    // rust/tests/integration_runtime.rs.
}
