//! Offline stub of the `xla` (PJRT) API surface the engine uses.
//!
//! The offline dependency universe has no `xla` crate (only `anyhow`
//! and `log` are real dependencies — DESIGN.md §3), so this module
//! provides the exact type/method surface `engine.rs` compiles
//! against and fails **at load time** with a clear message. Every
//! artifacts-dependent path (tests, examples, `serve`/`generate`)
//! already self-skips when `artifacts/` is absent, so the stub is
//! never reached in CI; on a machine with a real PJRT runtime, swap
//! this module for the real `xla` crate — the engine code needs no
//! changes.

use std::path::Path;

use anyhow::{bail, Result};

const UNAVAILABLE: &str = "PJRT runtime unavailable: this build uses the offline `xla` \
     stub (see rust/src/runtime/xla.rs). Link the real `xla` crate to load artifacts.";

#[derive(Debug)]
pub struct PjRtClient;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

#[derive(Debug)]
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        bail!(UNAVAILABLE)
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}
