//! Rust-side model runtime: PJRT engine over the AOT artifacts.
//!
//! Layer responsibilities (see DESIGN.md):
//! * python/compile (build time): author + lower the model to HLO text.
//! * here (run time): parse, compile, execute — no Python.

pub mod engine;
pub mod tokenizer;
pub mod weights;
mod xla;

pub use engine::{argmax, Engine, GenStats, KvCache, ModelMeta};
pub use weights::{Tensor, Weights};

use crate::util::args::{usage, Args, OptSpec};
use anyhow::Result;

/// `icc6g generate` — one-shot generation through the artifacts.
pub fn cli_generate(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "prompt", help: "input text", takes_value: true,
                  default: Some("The 6G network integrates communication and computing.") },
        OptSpec { name: "tokens", help: "output tokens", takes_value: true, default: Some("15") },
        OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv.iter().cloned(), &specs)?;
    if args.flag("help") {
        print!("{}", usage("icc6g generate", "One-shot generation via AOT artifacts", &specs));
        return Ok(());
    }
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Engine::default_artifacts_dir);
    let n_out = args.get_usize("tokens")?.unwrap();
    let prompt_text = args.get("prompt").unwrap();

    let t0 = std::time::Instant::now();
    let engine = Engine::load(&dir)?;
    println!(
        "engine loaded in {:.2}s ({} params, vocab {}, max_seq {})",
        t0.elapsed().as_secs_f64(),
        engine.meta.n_params,
        engine.meta.vocab,
        engine.meta.max_seq
    );

    let mut prompt = tokenizer::encode(prompt_text);
    let limit = engine.meta.max_seq.saturating_sub(n_out).max(1);
    prompt.truncate(limit);
    let (out, stats) = engine.generate(&prompt, n_out)?;
    println!("prompt tokens : {}", prompt.len());
    println!("output tokens : {:?}", out);
    println!("output text   : {:?}", tokenizer::decode(&out));
    println!(
        "prefill {:.1} ms | decode {:.1} ms | {:.1} tok/s",
        stats.prefill_s * 1e3,
        stats.decode_s * 1e3,
        stats.tokens_per_sec()
    );
    Ok(())
}
