//! Loader for `artifacts/weights.bin` (format defined in
//! python/compile/aot.py):
//!
//! ```text
//! magic "ICCW" | u32 version=1 | u32 n_tensors
//! per tensor: u32 name_len | name | u32 rank | u32 dims[rank] | f32 data
//! ```
//!
//! Tensor order in the file is the model's canonical parameter order
//! and must match the HLO argument order of prefill/decode.

use anyhow::{bail, Context, Result};

/// One parameter tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// All model parameters, in canonical (= HLO argument) order.
#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: Vec<Tensor>,
}

impl Weights {
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let data = std::fs::read(path)
            .with_context(|| format!("reading weights from {}", path.display()))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<Self> {
        let mut cur = Cursor { data, off: 0 };
        let magic = cur.bytes(4)?;
        if magic != b"ICCW" {
            bail!("bad magic {magic:?} (expected ICCW)");
        }
        let version = cur.u32()?;
        if version != 1 {
            bail!("unsupported weights version {version}");
        }
        let n = cur.u32()? as usize;
        if n == 0 || n > 4096 {
            bail!("implausible tensor count {n}");
        }
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = cur.u32()? as usize;
            if name_len > 256 {
                bail!("implausible name length {name_len}");
            }
            let name = String::from_utf8(cur.bytes(name_len)?.to_vec())
                .context("tensor name not utf-8")?;
            let rank = cur.u32()? as usize;
            if rank > 8 {
                bail!("implausible rank {rank} for '{name}'");
            }
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(cur.u32()? as usize);
            }
            let count: usize = dims.iter().product();
            let raw = cur.bytes(count * 4)?;
            let mut vals = vec![0f32; count];
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.push(Tensor { name, dims, data: vals });
        }
        if cur.off != data.len() {
            bail!("{} trailing bytes after last tensor", data.len() - cur.off);
        }
        Ok(Self { tensors })
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(Tensor::element_count).sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }
}

struct Cursor<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.data.len() {
            bail!("weights file truncated at offset {}", self.off);
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(tensors: &[(&str, &[u32], &[f32])]) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend(b"ICCW");
        v.extend(1u32.to_le_bytes());
        v.extend((tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            v.extend((name.len() as u32).to_le_bytes());
            v.extend(name.as_bytes());
            v.extend((dims.len() as u32).to_le_bytes());
            for d in *dims {
                v.extend(d.to_le_bytes());
            }
            for x in *data {
                v.extend(x.to_le_bytes());
            }
        }
        v
    }

    #[test]
    fn roundtrip() {
        let data = encode(&[
            ("a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            ("b", &[2], &[-1.0, 0.5]),
        ]);
        let w = Weights::parse(&data).unwrap();
        assert_eq!(w.tensors.len(), 2);
        assert_eq!(w.tensors[0].name, "a");
        assert_eq!(w.tensors[0].dims, vec![2, 3]);
        assert_eq!(w.tensors[1].data, vec![-1.0, 0.5]);
        assert_eq!(w.total_params(), 8);
        assert!(w.by_name("b").is_some());
        assert!(w.by_name("zz").is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut data = encode(&[("a", &[1], &[1.0])]);
        data[0] = b'X';
        assert!(Weights::parse(&data).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let data = encode(&[("a", &[4], &[1.0, 2.0, 3.0, 4.0])]);
        assert!(Weights::parse(&data[..data.len() - 3]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut data = encode(&[("a", &[1], &[1.0])]);
        data.push(0);
        assert!(Weights::parse(&data).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut data = encode(&[("a", &[1], &[1.0])]);
        data[4] = 9;
        assert!(Weights::parse(&data).is_err());
    }
}
