//! Elastic compute control plane (DESIGN.md §11).
//!
//! The scenario engine's compute tier is a fixed set of always-healthy
//! nodes; this module layers a *managed cluster* on top of it:
//!
//! * a per-node **lifecycle state machine**
//!   (`Provisioning → Up → Draining → Down`) driven by deterministic
//!   MTBF/MTTR failure and repair events on the engine's event
//!   calendar, with a per-node spin-up delay;
//! * an [`AutoscalerPolicy`] evaluated on a coarse **control tick**
//!   (queue-depth and TTFT-SLO-violation triggers ship as built-ins;
//!   the fixed policy never acts, making an enabled-but-idle cluster
//!   behave exactly like the static tier);
//! * **re-dispatch** bookkeeping for jobs evicted from a failed node
//!   (the engine re-routes them through its `Routing` policy; this
//!   module tracks retry budgets and lost work);
//! * **cost/energy accounting**: powered wall-seconds per node turn
//!   into GPU-seconds, joules and dollars from the [`GpuSpec`]
//!   TDP/price catalog fields, aggregated per node and per class.
//!
//! Everything here is a passive state machine like `ComputeNode`: the
//! engine owns the calendar and drives [`ClusterRt`] with explicit
//! transitions, so the module stays trivially unit-testable and the
//! disabled path (no `ClusterRt` at all) is bit-identical to the
//! static tier by construction.
//!
//! Determinism: failure and repair delays for node `i` are drawn from
//! the dedicated RNG substream `NODE_CHURN_STREAM + i` of the master
//! seed — disjoint from every radio/traffic/service substream — and
//! all control-plane logic runs serially on the engine thread, so runs
//! are reproducible per seed and invariant to the worker-thread count.

use crate::llm::GpuSpec;
use crate::metrics::{ClassClusterReport, ClusterReport, NodeClusterReport};
use crate::rng::Rng;

/// Base RNG substream id for per-node failure/repair draws: node `i`
/// draws from `substream(master_seed, NODE_CHURN_STREAM + i)`. The
/// high base keeps the range disjoint from the per-cell radio streams
/// (≤ `0x4000_0000_0000 + ue`) and every per-(cell, ue) traffic
/// stream.
pub const NODE_CHURN_STREAM: u64 = 0x8000_0000_0000;

/// Lifecycle state of one compute node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Powered on, paying cost, not yet serving (spin-up window).
    Provisioning,
    /// Healthy and eligible for routing.
    Up,
    /// Excluded from routing; finishes owned work, then powers off.
    Draining,
    /// Powered off: no cost, no work. Reached by failure or scale-down.
    Down,
}

impl NodeState {
    /// Powered states accrue cost (you pay while booting and draining).
    pub fn powered(self) -> bool {
        self != NodeState::Down
    }

    /// Stable wire discriminant (engine snapshots).
    pub(crate) fn to_u8(self) -> u8 {
        match self {
            NodeState::Provisioning => 0,
            NodeState::Up => 1,
            NodeState::Draining => 2,
            NodeState::Down => 3,
        }
    }

    /// Inverse of [`NodeState::to_u8`].
    pub(crate) fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => NodeState::Provisioning,
            1 => NodeState::Up,
            2 => NodeState::Draining,
            3 => NodeState::Down,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            NodeState::Provisioning => "provisioning",
            NodeState::Up => "up",
            NodeState::Draining => "draining",
            NodeState::Down => "down",
        }
    }
}

/// Per-node churn parameters (TOML `[[node]] mtbf/mttr/spinup`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeChurnSpec {
    /// Mean time between failures, seconds (`∞` = never fails).
    pub mtbf: f64,
    /// Mean time to repair, seconds (exponential draw).
    pub mttr: f64,
    /// Deterministic boot delay from power-on to serving, seconds.
    pub spinup: f64,
}

impl Default for NodeChurnSpec {
    fn default() -> Self {
        Self { mtbf: f64::INFINITY, mttr: 60.0, spinup: 30.0 }
    }
}

/// Cluster-wide control-plane parameters (TOML `[cluster]`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    pub policy: AutoscalerKind,
    /// Control-tick period, seconds.
    pub tick_s: f64,
    /// Autoscaler never powers fewer nodes than this.
    pub min_nodes: usize,
    /// Autoscaler never powers more nodes than this (clamped to the
    /// tier size at build time).
    pub max_nodes: usize,
    /// Times a job may be re-dispatched after node loss before it is
    /// declared lost.
    pub retry_budget: u32,
    /// TTFT target, seconds — jobs slower than this count as SLO
    /// violations in the control-tick observation window.
    pub ttft_slo: f64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            policy: AutoscalerKind::Fixed,
            tick_s: 0.5,
            min_nodes: 1,
            max_nodes: usize::MAX,
            retry_budget: 1,
            ttft_slo: 0.5,
        }
    }
}

/// What the autoscaler sees at each control tick — cheap aggregate
/// load summaries, mirroring [`crate::scenario::NodeView`]'s "what an
/// orchestrator can actually observe" discipline.
#[derive(Debug, Clone, Copy)]
pub struct ClusterObs {
    pub now: f64,
    /// Nodes currently powered and not draining (`Up` + `Provisioning`)
    /// — the capacity the tier is committed to.
    pub powered: usize,
    /// Nodes currently serving (`Up`).
    pub up: usize,
    /// Jobs queued across `Up` nodes.
    pub queued: usize,
    /// Busy servers / occupied batch slots across `Up` nodes.
    pub busy: u32,
    /// TTFT observations since the previous tick…
    pub jobs_ttft: u64,
    /// …of which exceeded [`ClusterSpec::ttft_slo`].
    pub ttft_violations: u64,
}

/// A scaling decision maker, evaluated once per control tick. Returns
/// the *desired* powered-node count; the runtime clamps it to
/// `[min_nodes, max_nodes]` and translates the delta into power-on /
/// drain transitions. Policies must be deterministic functions of the
/// observation (no RNG, no wall clock).
pub trait AutoscalerPolicy: std::fmt::Debug {
    fn name(&self) -> &'static str;
    fn desired(&mut self, obs: &ClusterObs) -> usize;
}

/// Never scales: desired = currently powered. With no churn this is
/// the static tier (pinned bit-identical by the integration property
/// test).
#[derive(Debug, Clone, Copy, Default)]
pub struct FixedPolicy;

impl AutoscalerPolicy for FixedPolicy {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn desired(&mut self, obs: &ClusterObs) -> usize {
        obs.powered
    }
}

/// Queue-depth trigger with hysteresis: add a node when the jobs in
/// system per `Up` node exceed `high`, release one when they fall
/// below `low` (`low < high` enforced at build time).
#[derive(Debug, Clone, Copy)]
pub struct QueueDepthPolicy {
    pub high: u32,
    pub low: u32,
}

impl AutoscalerPolicy for QueueDepthPolicy {
    fn name(&self) -> &'static str {
        "queue_depth"
    }

    fn desired(&mut self, obs: &ClusterObs) -> usize {
        let up = obs.up.max(1);
        let load = obs.queued + obs.busy as usize;
        if load > self.high as usize * up {
            obs.powered + 1
        } else if load < self.low as usize * up {
            obs.powered.saturating_sub(1)
        } else {
            obs.powered
        }
    }
}

/// TTFT-SLO trigger: add a node when the fraction of jobs violating
/// the TTFT target since the last tick exceeds `max_violation_frac`,
/// release one after a violation-free window.
#[derive(Debug, Clone, Copy)]
pub struct TtftSloPolicy {
    pub max_violation_frac: f64,
}

impl AutoscalerPolicy for TtftSloPolicy {
    fn name(&self) -> &'static str {
        "ttft_slo"
    }

    fn desired(&mut self, obs: &ClusterObs) -> usize {
        if obs.jobs_ttft == 0 {
            return obs.powered;
        }
        let frac = obs.ttft_violations as f64 / obs.jobs_ttft as f64;
        if frac > self.max_violation_frac {
            obs.powered + 1
        } else if obs.ttft_violations == 0 {
            obs.powered.saturating_sub(1)
        } else {
            obs.powered
        }
    }
}

/// Config-level autoscaler selector (`[cluster] policy = "..."`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AutoscalerKind {
    /// No scaling — the static tier plus (optionally) churn.
    Fixed,
    QueueDepth { high: u32, low: u32 },
    TtftSlo { max_violation_frac: f64 },
}

/// Default queue-depth thresholds: scale up beyond 8 jobs in system
/// per node, release below 1.
pub const DEFAULT_QUEUE_HIGH: u32 = 8;
pub const DEFAULT_QUEUE_LOW: u32 = 1;
/// Default tolerated TTFT-violation fraction per tick window.
pub const DEFAULT_VIOLATION_FRAC: f64 = 0.05;

impl AutoscalerKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "none" | "static" => Some(Self::Fixed),
            "queue_depth" | "queue-depth" | "queue" => {
                Some(Self::QueueDepth { high: DEFAULT_QUEUE_HIGH, low: DEFAULT_QUEUE_LOW })
            }
            "ttft_slo" | "ttft-slo" | "ttft" | "slo" => {
                Some(Self::TtftSlo { max_violation_frac: DEFAULT_VIOLATION_FRAC })
            }
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Fixed => "fixed",
            Self::QueueDepth { .. } => "queue_depth",
            Self::TtftSlo { .. } => "ttft_slo",
        }
    }

    pub fn build(self) -> Box<dyn AutoscalerPolicy> {
        match self {
            Self::Fixed => Box::new(FixedPolicy),
            Self::QueueDepth { high, low } => Box::new(QueueDepthPolicy { high, low }),
            Self::TtftSlo { max_violation_frac } => {
                Box::new(TtftSloPolicy { max_violation_frac })
            }
        }
    }
}

/// Raw per-node accounting counters (costs are priced at report time
/// from the node's [`GpuSpec`]).
#[derive(Debug, Clone, Copy, Default)]
struct NodeAcct {
    up_seconds: f64,
    served: u64,
    redispatched: u64,
    lost: u64,
    failures: u64,
}

/// Per-class attributed work (roofline seconds priced on the serving
/// node — see DESIGN.md §11 for the formulas).
#[derive(Debug, Clone, Copy, Default)]
struct ClassAcct {
    gpu_seconds: f64,
    joules: f64,
    dollars: f64,
    redispatched: u64,
    lost: u64,
}

/// Flat dump of [`ClusterRt`]'s mutable state for engine snapshots.
/// The spec, policy object, churn table and GPU catalog are
/// config-derived and rebuilt from the scenario; the built-in
/// autoscaler policies are stateless, so the policy needs no capture.
#[derive(Debug, Clone)]
pub(crate) struct ClusterRtState {
    /// [`NodeState::to_u8`] discriminants, one per node.
    pub states: Vec<u8>,
    pub epochs: Vec<u32>,
    pub repairing: Vec<bool>,
    /// Per-node churn RNG stream positions.
    pub rngs: Vec<([u64; 4], Option<f64>)>,
    pub powered_since: Vec<f64>,
    /// `(up_seconds, served, redispatched, lost, failures)` per node.
    pub acct: Vec<(f64, u64, u64, u64, u64)>,
    /// `(gpu_seconds, joules, dollars, redispatched, lost)` per class.
    pub class_acct: Vec<(f64, f64, f64, u64, u64)>,
    pub jobs_ttft: u64,
    pub ttft_violations: u64,
}

/// Runtime control-plane state for one scenario run. Owned and driven
/// serially by the scenario engine; every method is a deterministic
/// transition.
#[derive(Debug)]
pub struct ClusterRt {
    spec: ClusterSpec,
    policy: Box<dyn AutoscalerPolicy>,
    churn: Vec<NodeChurnSpec>,
    gpus: Vec<GpuSpec>,
    states: Vec<NodeState>,
    /// Bumped whenever node `i` loses its in-flight calendar events
    /// (failure, drain-complete); events carrying an older epoch are
    /// stale and must be ignored.
    epochs: Vec<u32>,
    /// A failed node awaiting its repair event cannot be powered on by
    /// the autoscaler.
    repairing: Vec<bool>,
    rngs: Vec<Rng>,
    /// When each powered node last transitioned into a powered state.
    powered_since: Vec<f64>,
    acct: Vec<NodeAcct>,
    class_acct: Vec<ClassAcct>,
    jobs_ttft: u64,
    ttft_violations: u64,
}

impl ClusterRt {
    /// All nodes start `Up` at t = 0 (the static tier's assumption).
    pub fn new(
        spec: ClusterSpec,
        churn: Vec<NodeChurnSpec>,
        gpus: Vec<GpuSpec>,
        n_classes: usize,
        master_seed: u64,
    ) -> Self {
        let n = gpus.len();
        assert_eq!(churn.len(), n, "one churn spec per node");
        assert!(spec.tick_s > 0.0);
        Self {
            spec,
            policy: spec.policy.build(),
            churn,
            gpus,
            states: vec![NodeState::Up; n],
            epochs: vec![0; n],
            repairing: vec![false; n],
            rngs: (0..n)
                .map(|i| Rng::substream(master_seed, NODE_CHURN_STREAM + i as u64))
                .collect(),
            powered_since: vec![0.0; n],
            acct: vec![NodeAcct::default(); n],
            class_acct: vec![ClassAcct::default(); n_classes],
            jobs_ttft: 0,
            ttft_violations: 0,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.states.len()
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    pub fn state(&self, node: usize) -> NodeState {
        self.states[node]
    }

    pub fn epoch(&self, node: usize) -> u32 {
        self.epochs[node]
    }

    /// Is an event stamped with `epoch` for this node still live?
    pub fn event_live(&self, node: usize, epoch: u32) -> bool {
        self.epochs[node] == epoch
    }

    /// Routing eligibility: only `Up` nodes receive new work.
    pub fn eligible(&self, node: usize) -> bool {
        self.states[node] == NodeState::Up
    }

    /// Draw the next time-to-failure for a node that just came `Up`
    /// (`None` when its MTBF is infinite — the node never fails).
    pub fn time_to_failure(&mut self, node: usize) -> Option<f64> {
        let mtbf = self.churn[node].mtbf;
        if !mtbf.is_finite() {
            return None;
        }
        assert!(mtbf > 0.0);
        Some(self.rngs[node].exp(1.0 / mtbf))
    }

    fn accrue(&mut self, node: usize, now: f64) {
        if self.states[node].powered() {
            self.acct[node].up_seconds += now - self.powered_since[node];
        }
    }

    /// Node `node` fails at `now`: power off, invalidate its in-flight
    /// events, and return the repair delay to schedule. The engine is
    /// responsible for evicting and re-dispatching the node's jobs.
    pub fn on_fail(&mut self, node: usize, now: f64) -> f64 {
        debug_assert!(self.states[node].powered(), "only powered nodes fail");
        self.accrue(node, now);
        self.states[node] = NodeState::Down;
        self.epochs[node] += 1;
        self.repairing[node] = true;
        self.acct[node].failures += 1;
        let mttr = self.churn[node].mttr;
        assert!(mttr.is_finite() && mttr > 0.0, "node {node} has no finite mttr");
        self.rngs[node].exp(1.0 / mttr)
    }

    /// Repair completes at `now`: the node powers back on and begins
    /// its spin-up. Returns the spin-up delay to schedule.
    pub fn on_repair(&mut self, node: usize, now: f64) -> f64 {
        debug_assert_eq!(self.states[node], NodeState::Down);
        self.repairing[node] = false;
        self.states[node] = NodeState::Provisioning;
        self.powered_since[node] = now;
        self.churn[node].spinup
    }

    /// Spin-up completes: the node starts serving. Returns the next
    /// time-to-failure to schedule (stamped with the current epoch).
    pub fn on_up(&mut self, node: usize, _now: f64) -> Option<f64> {
        debug_assert_eq!(self.states[node], NodeState::Provisioning);
        self.states[node] = NodeState::Up;
        self.time_to_failure(node)
    }

    /// TTFT observation for the current tick window.
    pub fn observe_ttft(&mut self, ttft: f64) {
        self.jobs_ttft += 1;
        if ttft > self.spec.ttft_slo {
            self.ttft_violations += 1;
        }
    }

    /// A job completed on `node`; `work_seconds` is its roofline
    /// prefill + decode time on that node (per-class cost attribution).
    pub fn observe_completion(&mut self, node: usize, class: usize, work_seconds: f64) {
        self.acct[node].served += 1;
        let g = &self.gpus[node];
        let c = &mut self.class_acct[class];
        c.gpu_seconds += work_seconds * g.scale;
        c.joules += work_seconds * g.tdp_watts;
        c.dollars += work_seconds / 3600.0 * g.price_per_hour;
    }

    /// A job evicted from `node` re-enters routing.
    pub fn observe_redispatch(&mut self, node: usize, class: usize) {
        self.acct[node].redispatched += 1;
        self.class_acct[class].redispatched += 1;
    }

    /// A job evicted from `node` exhausted its retry budget.
    pub fn observe_lost(&mut self, node: usize, class: usize) {
        self.acct[node].lost += 1;
        self.class_acct[class].lost += 1;
    }

    /// One control tick: complete drains, evaluate the autoscaler, and
    /// apply scale decisions. `loads[i] = (queue_len, busy)` for every
    /// node (stale values for non-`Up` nodes are ignored, except that
    /// a `Draining` node with zero load powers off). Nodes to power on
    /// are appended to `power_on`; the engine schedules their `NodeUp`
    /// events `spinup(node)` seconds out.
    pub fn control_tick(
        &mut self,
        now: f64,
        loads: &[(usize, u32)],
        power_on: &mut Vec<usize>,
    ) {
        let n = self.n_nodes();
        assert_eq!(loads.len(), n);
        // 1. drained nodes that went idle power off
        for i in 0..n {
            if self.states[i] == NodeState::Draining && loads[i] == (0, 0) {
                self.accrue(i, now);
                self.states[i] = NodeState::Down;
                self.epochs[i] += 1; // invalidate the pending failure event
            }
        }
        // 2. observe and decide
        let up = self.states.iter().filter(|s| **s == NodeState::Up).count();
        let powered = self
            .states
            .iter()
            .filter(|s| matches!(s, NodeState::Up | NodeState::Provisioning))
            .count();
        let (mut queued, mut busy) = (0usize, 0u32);
        for i in 0..n {
            if self.states[i] == NodeState::Up {
                queued += loads[i].0;
                busy += loads[i].1;
            }
        }
        let obs = ClusterObs {
            now,
            powered,
            up,
            queued,
            busy,
            jobs_ttft: self.jobs_ttft,
            ttft_violations: self.ttft_violations,
        };
        let desired = self
            .policy
            .desired(&obs)
            .clamp(self.spec.min_nodes, self.spec.max_nodes.min(n));
        self.jobs_ttft = 0;
        self.ttft_violations = 0;
        // 3. apply the delta
        if desired > powered {
            let mut need = desired - powered;
            // un-draining is free capacity (no spin-up) — use it first
            for i in 0..n {
                if need == 0 {
                    break;
                }
                if self.states[i] == NodeState::Draining {
                    self.states[i] = NodeState::Up;
                    need -= 1;
                }
            }
            for i in 0..n {
                if need == 0 {
                    break;
                }
                if self.states[i] == NodeState::Down && !self.repairing[i] {
                    self.states[i] = NodeState::Provisioning;
                    self.powered_since[i] = now;
                    power_on.push(i);
                    need -= 1;
                }
            }
        } else if desired < powered {
            // release the highest indices first: the default routing
            // affinities (class % n, cell % n) keep low indices warm
            let mut excess = powered - desired;
            for i in (0..n).rev() {
                if excess == 0 {
                    break;
                }
                if self.states[i] == NodeState::Up {
                    self.states[i] = NodeState::Draining;
                    excess -= 1;
                }
            }
        }
    }

    /// Capture the mutable control-plane state for an engine snapshot.
    pub(crate) fn snapshot_state(&self) -> ClusterRtState {
        ClusterRtState {
            states: self.states.iter().map(|s| s.to_u8()).collect(),
            epochs: self.epochs.clone(),
            repairing: self.repairing.clone(),
            rngs: self.rngs.iter().map(|r| r.snapshot_state()).collect(),
            powered_since: self.powered_since.clone(),
            acct: self
                .acct
                .iter()
                .map(|a| (a.up_seconds, a.served, a.redispatched, a.lost, a.failures))
                .collect(),
            class_acct: self
                .class_acct
                .iter()
                .map(|c| (c.gpu_seconds, c.joules, c.dollars, c.redispatched, c.lost))
                .collect(),
            jobs_ttft: self.jobs_ttft,
            ttft_violations: self.ttft_violations,
        }
    }

    /// Overwrite the mutable state of a freshly-constructed runtime
    /// with a checkpoint (inverse of [`ClusterRt::snapshot_state`]).
    pub(crate) fn restore_state(&mut self, st: ClusterRtState) {
        assert_eq!(st.states.len(), self.n_nodes(), "snapshot node count mismatch");
        assert_eq!(st.class_acct.len(), self.class_acct.len(), "snapshot class count mismatch");
        self.states = st
            .states
            .iter()
            .map(|&v| NodeState::from_u8(v).expect("invalid NodeState discriminant"))
            .collect();
        self.epochs = st.epochs;
        self.repairing = st.repairing;
        self.rngs = st.rngs.into_iter().map(|(s, g)| Rng::from_state(s, g)).collect();
        self.powered_since = st.powered_since;
        self.acct = st
            .acct
            .into_iter()
            .map(|(up_seconds, served, redispatched, lost, failures)| NodeAcct {
                up_seconds,
                served,
                redispatched,
                lost,
                failures,
            })
            .collect();
        self.class_acct = st
            .class_acct
            .into_iter()
            .map(|(gpu_seconds, joules, dollars, redispatched, lost)| ClassAcct {
                gpu_seconds,
                joules,
                dollars,
                redispatched,
                lost,
            })
            .collect();
        self.jobs_ttft = st.jobs_ttft;
        self.ttft_violations = st.ttft_violations;
    }

    /// Close the books at the end of the run.
    pub fn finalize(&mut self, t_end: f64) {
        for i in 0..self.n_nodes() {
            self.accrue(i, t_end);
            // freeze: everything is accounted through t_end
            self.powered_since[i] = t_end;
        }
    }

    /// Price the raw counters into the report section (call after
    /// [`ClusterRt::finalize`]).
    pub fn report(&self, class_names: &[String]) -> ClusterReport {
        assert_eq!(class_names.len(), self.class_acct.len());
        let nodes = (0..self.n_nodes())
            .map(|i| {
                let g = &self.gpus[i];
                let a = &self.acct[i];
                NodeClusterReport {
                    name: format!("node{i}"),
                    gpu: g.display_name(),
                    up_seconds: a.up_seconds,
                    gpu_seconds: a.up_seconds * g.scale,
                    joules: a.up_seconds * g.tdp_watts,
                    dollars: a.up_seconds / 3600.0 * g.price_per_hour,
                    served: a.served,
                    redispatched: a.redispatched,
                    lost: a.lost,
                    failures: a.failures,
                }
            })
            .collect();
        let classes = class_names
            .iter()
            .zip(&self.class_acct)
            .map(|(name, c)| ClassClusterReport {
                name: name.clone(),
                gpu_seconds: c.gpu_seconds,
                joules: c.joules,
                dollars: c.dollars,
                redispatched: c.redispatched,
                lost: c.lost,
            })
            .collect();
        ClusterReport { nodes, classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpus(n: usize) -> Vec<GpuSpec> {
        vec![GpuSpec::a100(); n]
    }

    fn churn_all(mtbf: f64, mttr: f64, spinup: f64, n: usize) -> Vec<NodeChurnSpec> {
        vec![NodeChurnSpec { mtbf, mttr, spinup }; n]
    }

    fn rt(n: usize, policy: AutoscalerKind) -> ClusterRt {
        let spec = ClusterSpec { policy, ..ClusterSpec::default() };
        ClusterRt::new(spec, vec![NodeChurnSpec::default(); n], gpus(n), 1, 42)
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(AutoscalerKind::parse("fixed"), Some(AutoscalerKind::Fixed));
        assert_eq!(AutoscalerKind::parse("none"), Some(AutoscalerKind::Fixed));
        assert_eq!(
            AutoscalerKind::parse("queue_depth"),
            Some(AutoscalerKind::QueueDepth {
                high: DEFAULT_QUEUE_HIGH,
                low: DEFAULT_QUEUE_LOW
            })
        );
        assert_eq!(
            AutoscalerKind::parse("ttft"),
            Some(AutoscalerKind::TtftSlo { max_violation_frac: DEFAULT_VIOLATION_FRAC })
        );
        assert_eq!(AutoscalerKind::parse("??"), None);
        for k in [
            AutoscalerKind::Fixed,
            AutoscalerKind::QueueDepth { high: 4, low: 1 },
            AutoscalerKind::TtftSlo { max_violation_frac: 0.1 },
        ] {
            assert_eq!(k.build().name(), k.name());
        }
    }

    #[test]
    fn nodes_start_up_and_fixed_policy_never_scales() {
        let mut c = rt(3, AutoscalerKind::Fixed);
        for i in 0..3 {
            assert_eq!(c.state(i), NodeState::Up);
            assert!(c.eligible(i));
        }
        let mut on = Vec::new();
        for t in 1..20 {
            c.control_tick(t as f64 * 0.5, &[(50, 1), (0, 0), (0, 0)], &mut on);
        }
        assert!(on.is_empty());
        for i in 0..3 {
            assert_eq!(c.state(i), NodeState::Up);
        }
    }

    #[test]
    fn infinite_mtbf_never_fails_and_draws_nothing() {
        let mut c = rt(2, AutoscalerKind::Fixed);
        let before = format!("{:?}", c.rngs[0]);
        assert_eq!(c.time_to_failure(0), None);
        assert_eq!(before, format!("{:?}", c.rngs[0]), "no RNG consumed");
    }

    #[test]
    fn failure_repair_cycle_walks_the_state_machine() {
        let spec = ClusterSpec::default();
        let mut c = ClusterRt::new(spec, churn_all(100.0, 30.0, 5.0, 2), gpus(2), 1, 7);
        let ttf = c.time_to_failure(0).unwrap();
        assert!(ttf > 0.0 && ttf.is_finite());
        let e0 = c.epoch(0);
        let repair_in = c.on_fail(0, 10.0);
        assert!(repair_in > 0.0 && repair_in.is_finite());
        assert_eq!(c.state(0), NodeState::Down);
        assert!(!c.eligible(0));
        assert_eq!(c.epoch(0), e0 + 1, "failure invalidates in-flight events");
        assert!(!c.event_live(0, e0));
        assert!(c.event_live(0, e0 + 1));
        // repair → provisioning with the configured spin-up
        let spin = c.on_repair(0, 40.0);
        assert_eq!(spin, 5.0);
        assert_eq!(c.state(0), NodeState::Provisioning);
        assert!(!c.eligible(0), "provisioning nodes are not routed to");
        assert!(c.on_up(0, 45.0).is_some());
        assert_eq!(c.state(0), NodeState::Up);
        // node 1 was untouched throughout
        assert_eq!(c.state(1), NodeState::Up);
        assert_eq!(c.epoch(1), 0);
    }

    #[test]
    fn failure_draws_are_deterministic_per_seed_and_node() {
        let mk = |seed| {
            let mut c = ClusterRt::new(
                ClusterSpec::default(),
                churn_all(100.0, 30.0, 5.0, 2),
                gpus(2),
                1,
                seed,
            );
            (c.time_to_failure(0).unwrap(), c.time_to_failure(1).unwrap())
        };
        let (a0, a1) = mk(1);
        let (b0, b1) = mk(1);
        assert_eq!(a0.to_bits(), b0.to_bits());
        assert_eq!(a1.to_bits(), b1.to_bits());
        assert_ne!(a0.to_bits(), a1.to_bits(), "per-node streams are independent");
        let (c0, _) = mk(2);
        assert_ne!(a0.to_bits(), c0.to_bits(), "master seed matters");
    }

    #[test]
    fn queue_depth_policy_scales_up_and_down_with_hysteresis() {
        let mut p = QueueDepthPolicy { high: 4, low: 1 };
        let obs = |queued, busy, up, powered| ClusterObs {
            now: 0.0,
            powered,
            up,
            queued,
            busy,
            jobs_ttft: 0,
            ttft_violations: 0,
        };
        assert_eq!(p.desired(&obs(9, 0, 2, 2)), 3, "9 > 4·2 → grow");
        assert_eq!(p.desired(&obs(8, 0, 2, 2)), 2, "8 = 4·2 → hold");
        assert_eq!(p.desired(&obs(1, 0, 2, 2)), 1, "1 < 1·2 → shrink");
        assert_eq!(p.desired(&obs(0, 2, 2, 2)), 2, "busy servers count as load");
    }

    #[test]
    fn ttft_policy_reacts_to_violation_fraction() {
        let mut p = TtftSloPolicy { max_violation_frac: 0.05 };
        let obs = |jobs, viol, powered| ClusterObs {
            now: 0.0,
            powered,
            up: powered,
            queued: 0,
            busy: 0,
            jobs_ttft: jobs,
            ttft_violations: viol,
        };
        assert_eq!(p.desired(&obs(0, 0, 2)), 2, "no observations → hold");
        assert_eq!(p.desired(&obs(100, 10, 2)), 3, "10% violations → grow");
        assert_eq!(p.desired(&obs(100, 0, 2)), 1, "clean window → shrink");
        assert_eq!(p.desired(&obs(100, 3, 2)), 2, "3% ≤ 5% but non-zero → hold");
    }

    #[test]
    fn control_tick_scales_up_through_provisioning_and_down_through_drain() {
        let spec = ClusterSpec {
            policy: AutoscalerKind::QueueDepth { high: 2, low: 1 },
            min_nodes: 1,
            ..ClusterSpec::default()
        };
        let mut c =
            ClusterRt::new(spec, churn_all(f64::INFINITY, 60.0, 10.0, 3), gpus(3), 1, 3);
        // shrink to min: everything idle → one release per tick
        let mut on = Vec::new();
        c.control_tick(0.5, &[(0, 0), (0, 0), (0, 0)], &mut on);
        assert!(on.is_empty());
        assert_eq!(c.state(2), NodeState::Draining, "highest index drains first");
        assert_eq!(c.state(0), NodeState::Up);
        // the idle draining node powers off on the next tick, and the
        // policy releases the next one
        c.control_tick(1.0, &[(0, 0), (0, 0), (0, 0)], &mut on);
        assert_eq!(c.state(2), NodeState::Down);
        assert_eq!(c.state(1), NodeState::Draining);
        // a still-busy draining node keeps running
        c.control_tick(1.5, &[(0, 0), (3, 1), (0, 0)], &mut on);
        assert_eq!(c.state(1), NodeState::Draining);
        assert!(on.is_empty());
        // load spike: un-drain first (free), then power on a Down node
        c.control_tick(2.0, &[(9, 1), (0, 0), (0, 0)], &mut on);
        assert_eq!(c.state(1), NodeState::Up, "draining node reclaimed without spin-up");
        on.clear();
        c.control_tick(2.5, &[(9, 1), (9, 1), (0, 0)], &mut on);
        assert_eq!(on, vec![2], "cold node powers on");
        assert_eq!(c.state(2), NodeState::Provisioning);
        assert!(c.on_up(2, 12.5).is_none(), "infinite mtbf → no failure event");
        assert_eq!(c.state(2), NodeState::Up);
    }

    #[test]
    fn autoscaler_never_powers_a_node_awaiting_repair() {
        let spec = ClusterSpec {
            policy: AutoscalerKind::QueueDepth { high: 1, low: 0 },
            ..ClusterSpec::default()
        };
        let mut c = ClusterRt::new(spec, churn_all(50.0, 1e9, 1.0, 2), gpus(2), 1, 5);
        c.on_fail(1, 1.0); // node 1 down, repair pending (mttr huge)
        let mut on = Vec::new();
        c.control_tick(1.5, &[(40, 1), (0, 0)], &mut on);
        assert!(on.is_empty(), "broken node must not be powered on");
        assert_eq!(c.state(1), NodeState::Down);
        // once repaired (and up), it can fail over again normally
        c.on_repair(1, 2.0);
        assert_eq!(c.state(1), NodeState::Provisioning);
    }

    #[test]
    fn min_and_max_nodes_clamp_desires() {
        let spec = ClusterSpec {
            policy: AutoscalerKind::QueueDepth { high: 1, low: 1 },
            min_nodes: 2,
            max_nodes: 2,
            ..ClusterSpec::default()
        };
        let mut c =
            ClusterRt::new(spec, churn_all(f64::INFINITY, 60.0, 1.0, 3), gpus(3), 1, 9);
        let mut on = Vec::new();
        // overload cannot push past max_nodes = 2: one node must drain
        c.control_tick(0.5, &[(50, 1), (50, 1), (50, 1)], &mut on);
        assert!(on.is_empty());
        assert_eq!(c.state(2), NodeState::Draining);
        // idle cannot shrink below min_nodes = 2
        for t in 2..10 {
            c.control_tick(t as f64 * 0.5, &[(0, 0), (0, 0), (0, 0)], &mut on);
        }
        assert!(on.is_empty());
        let up: usize =
            (0..3).filter(|&i| c.state(i) == NodeState::Up).count();
        assert_eq!(up, 2);
    }

    #[test]
    fn accounting_prices_up_time_on_the_node_spec() {
        let spec = ClusterSpec::default();
        let g = GpuSpec::a100().scaled(2.0);
        let mut c = ClusterRt::new(
            spec,
            churn_all(100.0, 30.0, 5.0, 1),
            vec![g],
            2,
            11,
        );
        // up from 0 to 10 s, down for repair, never returns
        c.on_fail(0, 10.0);
        c.observe_redispatch(0, 1);
        c.observe_lost(0, 1);
        c.finalize(20.0);
        let rep = c.report(&["a".into(), "b".into()]);
        assert_eq!(rep.nodes.len(), 1);
        let n = &rep.nodes[0];
        assert_eq!(n.name, "node0");
        assert_eq!(n.gpu, "A100-SXM-80GB x2");
        assert!((n.up_seconds - 10.0).abs() < 1e-12);
        assert!((n.gpu_seconds - 20.0).abs() < 1e-12, "2× pool → 2 GPU-s per wall-s");
        assert!((n.joules - 10.0 * 800.0).abs() < 1e-9, "TDP scales with the pool");
        assert!((n.dollars - 10.0 / 3600.0 * 2.0 * 1.79).abs() < 1e-12);
        assert_eq!(n.failures, 1);
        assert_eq!(n.redispatched, 1);
        assert_eq!(n.lost, 1);
        assert_eq!(rep.classes.len(), 2);
        assert_eq!(rep.classes[1].redispatched, 1);
        assert_eq!(rep.classes[1].lost, 1);
        assert_eq!(rep.classes[0].redispatched, 0);
    }

    #[test]
    fn per_class_work_attribution_uses_the_serving_node_price() {
        let mut c = ClusterRt::new(
            ClusterSpec::default(),
            vec![NodeChurnSpec::default(); 2],
            vec![GpuSpec::a100(), GpuSpec::h100()],
            2,
            13,
        );
        c.observe_completion(0, 0, 2.0); // 2 s of A100 work for class 0
        c.observe_completion(1, 1, 1.0); // 1 s of H100 work for class 1
        c.finalize(5.0);
        let rep = c.report(&["x".into(), "y".into()]);
        assert!((rep.classes[0].joules - 2.0 * 400.0).abs() < 1e-9);
        assert!((rep.classes[1].joules - 700.0).abs() < 1e-9);
        assert!((rep.classes[0].dollars - 2.0 / 3600.0 * 1.79).abs() < 1e-15);
        assert_eq!(rep.nodes[0].served, 1);
        assert_eq!(rep.nodes[1].served, 1);
        // both nodes stayed up the whole 5 s window
        assert!((rep.nodes[0].up_seconds - 5.0).abs() < 1e-12);
        assert!((rep.total_dollars()
            - (5.0 / 3600.0 * 1.79 + 5.0 / 3600.0 * 2.99))
            .abs()
            < 1e-12);
    }

    #[test]
    fn ttft_observations_reset_each_tick() {
        let mut c = rt(1, AutoscalerKind::TtftSlo { max_violation_frac: 0.5 });
        c.observe_ttft(10.0); // violation (slo = 0.5)
        c.observe_ttft(0.1);
        assert_eq!(c.jobs_ttft, 2);
        assert_eq!(c.ttft_violations, 1);
        let mut on = Vec::new();
        c.control_tick(0.5, &[(0, 0)], &mut on);
        assert_eq!(c.jobs_ttft, 0, "window resets");
        assert_eq!(c.ttft_violations, 0);
    }
}
