//! Iteration-level continuous-batching execution engine.
//!
//! Real edge LLM serving does not occupy one server per job for the
//! whole request: it admits prefills against a KV-cache memory budget
//! and runs *batched decode steps*, amortizing the weight stream
//! across the batch (mixed-workload edge studies, arXiv:2411.17712,
//! show these batching dynamics dominate tail latency). The
//! [`BatchEngine`] models exactly that at iteration granularity:
//!
//! * **Admission** happens only at iteration boundaries, in the
//!   [`Discipline`] order (FIFO or ICC deadline priority with the
//!   hopeless-drop rule), gated by the batch-slot cap `max_batch` and
//!   the KV budget: a job reserves `(N_input + N_output) ·
//!   kv_bytes_per_token` for its whole lifetime (vLLM-style
//!   conservative reservation, which keeps admission deterministic).
//!   Jobs carrying a shared system-prompt prefix (`prefix_tokens > 0`)
//!   reserve only their private suffix when the prefix block is
//!   already resident: the block itself is refcounted and freed when
//!   the last referencing job leaves the batch, and a warm admission
//!   prefills only the non-shared input tokens.
//! * **One iteration** = the prefills of newly admitted jobs plus one
//!   batched decode step for every already-prefilled job:
//!   `τ = Σ prefill_j + max(Σ C_LLM,j / G_comp, max M_LLM,j / G_membw)`
//!   — the weight stream is charged once per step (the `max` over
//!   models in the batch), compute scales with batch size. For a
//!   homogeneous batch of size B this is exactly
//!   [`crate::llm::CostModel::batched_token_latency`].
//! * Every prefilled job emits one token per iteration; its first
//!   emitted token marks TTFT, its last completes the job and frees
//!   its KV reservation.
//!
//! With `max_batch = 1` the engine degenerates to the sequential
//! single-server node: one prefill iteration followed by `N_output`
//! decode iterations of `max(C/G_comp, M/G_membw)` each — the same
//! service time, admission order, and drop decisions as
//! [`super::ComputeNode`] (modulo f64 accumulation order).
//!
//! Like [`super::ComputeNode`], the engine is a passive state machine:
//! the simulator calls [`BatchEngine::enqueue`] on arrivals and
//! [`BatchEngine::step`] at each boundary the engine announced via
//! [`BatchEvent::StepAt`], and events drain into a caller-provided
//! buffer (allocation-free hot path).

use crate::llm::GpuSpec;

use super::{Discipline, ReadyQueue};

/// How a compute node executes its jobs.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ExecutionModel {
    /// Whole-job server occupancy (the paper's Figs 4/6/7 model): each
    /// job holds one of `n_servers` servers for its roofline service
    /// time.
    #[default]
    Sequential,
    /// Iteration-level continuous batching on a single engine.
    /// `kv_budget` is the KV-cache byte budget gating admission;
    /// `0.0` means "derive at build time" (`mem_bytes − max m_llm`).
    ContinuousBatching { max_batch: u32, kv_budget: f64 },
}

impl ExecutionModel {
    pub fn is_batching(&self) -> bool {
        matches!(self, ExecutionModel::ContinuousBatching { .. })
    }
}

/// A job as seen by the batch engine: the prefill/decode split demand
/// plus the per-token roofline constants of the served model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchJob {
    pub job_id: u64,
    /// Generation time at the UE.
    pub t_gen: f64,
    /// Observed communication latency (UE→BS, incl. uplink queueing).
    pub t_comm: f64,
    /// Absolute deadline `t_gen + b_total`.
    pub deadline: f64,
    pub n_input: u32,
    /// Output length (≥ 1) realized by the service model.
    pub n_output: u32,
    /// Prefill latency on this node (Eq 7).
    pub prefill_time: f64,
    /// *Sequential* decode latency `N_output · max(C/G_comp, M/G_membw)`
    /// — the lower bound used by the hopeless-drop rule (a batched
    /// step is never faster than a lone one).
    pub decode_time: f64,
    /// FLOPs per decode token (compute share of a batched step).
    pub c_llm: f64,
    /// Model bytes streamed per forward pass (amortized across the
    /// batch).
    pub m_llm: f64,
    /// KV-cache bytes reserved per token of context.
    pub kv_bytes_per_token: f64,
    /// Shared-prefix block key (system-prompt identity); meaningful
    /// only when `prefix_tokens > 0`.
    pub prefix_id: u64,
    /// Leading tokens of `n_input` shared with every other job
    /// carrying the same `prefix_id` (0 = no shared prefix; such jobs
    /// take the legacy admission path unchanged).
    pub prefix_tokens: u32,
}

impl BatchJob {
    /// ICC priority key (same as [`super::ComputeJob::priority_key`]).
    pub fn priority_key(&self) -> f64 {
        self.deadline - self.t_comm
    }

    /// KV bytes this job reserves while admitted (full context — the
    /// cold-prefix / no-prefix reservation).
    pub fn kv_bytes(&self) -> f64 {
        (self.n_input + self.n_output) as f64 * self.kv_bytes_per_token
    }

    /// KV bytes of the shared prefix block.
    pub fn prefix_kv_bytes(&self) -> f64 {
        self.prefix_tokens as f64 * self.kv_bytes_per_token
    }

    /// KV bytes private to this job when its prefix block is already
    /// resident: the non-shared input suffix plus the output tokens.
    pub fn suffix_kv_bytes(&self) -> f64 {
        (self.n_input - self.prefix_tokens + self.n_output) as f64 * self.kv_bytes_per_token
    }

    /// Lower bound on remaining service (prefill + lone decode).
    fn min_service_time(&self) -> f64 {
        self.prefill_time + self.decode_time
    }
}

/// What happened at an engine interaction. All events refer to the
/// `now` of the triggering call except [`BatchEvent::StepAt`], which
/// announces the *next* iteration boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchEvent {
    /// Admitted into the running batch; its prefill starts now (this
    /// is the job's service-start time).
    Admitted { job_id: u64 },
    /// First output token emitted (the TTFT boundary).
    FirstToken { job_id: u64 },
    /// Last output token emitted; KV reservation freed.
    Finished { job_id: u64 },
    /// Dropped at admission: hopeless deadline, or a KV demand larger
    /// than the whole budget (which could never be admitted).
    Dropped { job_id: u64 },
    /// The caller must invoke [`BatchEngine::step`] at absolute time
    /// `at` (exactly one is outstanding while the engine runs).
    StepAt { at: f64 },
}

#[derive(Debug, Clone, Copy)]
struct Active {
    job: BatchJob,
    tokens_left: u32,
    /// Prefill iteration completed → decodes one token per step.
    prefilled: bool,
    /// KV bytes this job reserved at admission (full context, or the
    /// private suffix only when its prefix block was already
    /// resident); released exactly once at finish/evict.
    kv_reserved: f64,
}

/// The continuous-batching execution engine of one compute node.
#[derive(Debug)]
pub struct BatchEngine {
    discipline: Discipline,
    gpu: GpuSpec,
    max_batch: usize,
    kv_budget: f64,
    kv_used: f64,
    queue: ReadyQueue<BatchJob>,
    active: Vec<Active>,
    /// Resident shared-prefix blocks: `(prefix_id, bytes, refcount)`.
    /// Linear scan — a node serves a handful of system-prompt classes,
    /// and the Vec keeps insertion order deterministic for snapshots.
    prefixes: Vec<(u64, f64, u32)>,
    /// A [`BatchEvent::StepAt`] is outstanding.
    running: bool,
    /// Running count of dropped jobs.
    pub dropped: u64,
}

impl BatchEngine {
    pub fn new(discipline: Discipline, gpu: GpuSpec, max_batch: u32, kv_budget: f64) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(kv_budget > 0.0, "kv_budget must be positive");
        Self {
            discipline,
            gpu,
            max_batch: max_batch as usize,
            kv_budget,
            kv_used: 0.0,
            queue: ReadyQueue::new(discipline),
            active: Vec::new(),
            prefixes: Vec::new(),
            running: false,
            dropped: 0,
        }
    }

    /// Jobs waiting for admission.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Jobs admitted (prefilling or decoding).
    pub fn batch_len(&self) -> usize {
        self.active.len()
    }

    /// KV bytes currently reserved.
    pub fn kv_used(&self) -> f64 {
        self.kv_used
    }

    /// Free KV bytes under the admission budget.
    pub fn kv_headroom(&self) -> f64 {
        (self.kv_budget - self.kv_used).max(0.0)
    }

    /// Is the shared-prefix block `key` resident?
    pub fn prefix_resident(&self, key: u64) -> bool {
        self.prefixes.iter().any(|p| p.0 == key)
    }

    /// Live references on prefix block `key` (0 when absent).
    pub fn prefix_refs(&self, key: u64) -> u32 {
        self.prefixes.iter().find(|p| p.0 == key).map_or(0, |p| p.2)
    }

    /// Nothing queued or admitted (a draining node at this point can
    /// power off).
    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.queue.len() == 0
    }

    /// Node-loss eviction: drain the admitted batch (in job-id order)
    /// and then the waiting queue (in discipline order) into `out`,
    /// releasing every KV reservation and cancelling the outstanding
    /// iteration. The caller must also invalidate the pending
    /// [`BatchEvent::StepAt`] it scheduled (the cluster layer does
    /// this with per-node event epochs).
    pub fn evict(&mut self, out: &mut Vec<BatchJob>) {
        self.active.sort_by_key(|a| a.job.job_id);
        for a in self.active.drain(..) {
            out.push(a.job);
        }
        self.queue.drain_into(out);
        self.kv_used = 0.0;
        self.prefixes.clear();
        self.running = false;
    }

    /// Engine-snapshot view of the dynamic state: `(kv_used, running,
    /// dropped, active batch as (job, tokens_left, prefilled,
    /// kv_reserved) tuples in stored order, waiting queue, resident
    /// prefix blocks)`. The active-batch order is preserved verbatim —
    /// it determines the prefill/decode sweep order of the next
    /// iteration; the prefix-block order is the residency order.
    #[allow(clippy::type_complexity)]
    pub(crate) fn snapshot_state(
        &self,
    ) -> (
        f64,
        bool,
        u64,
        Vec<(BatchJob, u32, bool, f64)>,
        (u64, Vec<(f64, u64, BatchJob)>),
        Vec<(u64, f64, u32)>,
    ) {
        (
            self.kv_used,
            self.running,
            self.dropped,
            self.active.iter().map(|a| (a.job, a.tokens_left, a.prefilled, a.kv_reserved)).collect(),
            self.queue.snapshot_entries(),
            self.prefixes.clone(),
        )
    }

    /// Rebuild an engine mid-run: config fields from the scenario
    /// spec, dynamic fields from a checkpoint.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn restore(
        discipline: Discipline,
        gpu: GpuSpec,
        max_batch: u32,
        kv_budget: f64,
        kv_used: f64,
        running: bool,
        dropped: u64,
        active: Vec<(BatchJob, u32, bool, f64)>,
        queue_seq: u64,
        queue_entries: Vec<(f64, u64, BatchJob)>,
        prefixes: Vec<(u64, f64, u32)>,
    ) -> Self {
        let mut e = Self::new(discipline, gpu, max_batch, kv_budget);
        e.kv_used = kv_used;
        e.running = running;
        e.dropped = dropped;
        e.active = active
            .into_iter()
            .map(|(job, tokens_left, prefilled, kv_reserved)| Active {
                job,
                tokens_left,
                prefilled,
                kv_reserved,
            })
            .collect();
        e.queue = ReadyQueue::restore(discipline, queue_seq, queue_entries);
        e.prefixes = prefixes;
        e
    }

    /// A job arrives at the node at time `now`. Events are appended to
    /// the caller's buffer (clear it between calls).
    pub fn enqueue(&mut self, job: BatchJob, now: f64, events: &mut Vec<BatchEvent>) {
        assert!(job.n_output >= 1, "jobs must decode at least one token");
        self.queue.push(job, job.priority_key());
        if !self.running {
            self.advance(now, events);
        }
    }

    /// The iteration boundary announced by the last
    /// [`BatchEvent::StepAt`] has been reached: account the elapsed
    /// iteration (prefills done, one token per decoding job), then
    /// admit and schedule the next iteration.
    pub fn step(&mut self, now: f64, events: &mut Vec<BatchEvent>) {
        assert!(self.running, "step() without an outstanding StepAt");
        self.running = false;
        let mut i = 0;
        let mut disturbed = false;
        while i < self.active.len() {
            let a = &mut self.active[i];
            if !a.prefilled {
                a.prefilled = true;
                i += 1;
                continue;
            }
            a.tokens_left -= 1;
            if a.tokens_left + 1 == a.job.n_output {
                events.push(BatchEvent::FirstToken { job_id: a.job.job_id });
            }
            if a.tokens_left == 0 {
                // `kv_reserved` is the exact f64 added at admission
                // (bit-identical to recomputing `kv_bytes()` on the
                // legacy no-prefix path).
                let reserved = a.kv_reserved;
                let job_id = a.job.job_id;
                let (pid, ptok) = (a.job.prefix_id, a.job.prefix_tokens);
                self.kv_used -= reserved;
                events.push(BatchEvent::Finished { job_id });
                self.active.swap_remove(i);
                if ptok > 0 {
                    self.release_prefix(pid);
                }
                disturbed = true;
            } else {
                i += 1;
            }
        }
        // swap_remove disturbs order; restore id order only on the
        // (rare) completion steps — iteration cost is order-invariant,
        // the sort just keeps event emission deterministic to read,
        // and the common one-token step must stay O(batch).
        if disturbed {
            self.active.sort_by_key(|a| a.job.job_id);
        }
        self.advance(now, events);
    }

    /// Release one reference on prefix block `key`, freeing its bytes
    /// when the last referencing job leaves the batch.
    fn release_prefix(&mut self, key: u64) {
        let i = self
            .prefixes
            .iter()
            .position(|p| p.0 == key)
            .expect("release of a non-resident prefix block");
        self.prefixes[i].2 -= 1;
        if self.prefixes[i].2 == 0 {
            self.kv_used -= self.prefixes[i].1;
            self.prefixes.remove(i);
        }
    }

    /// Admit from the queue and schedule the next iteration boundary.
    fn advance(&mut self, now: f64, events: &mut Vec<BatchEvent>) {
        loop {
            if self.active.len() >= self.max_batch {
                break;
            }
            let Some(head) = self.queue.peek() else { break };
            // Shared-prefix reuse: a job whose prefix block is already
            // resident reserves only its private suffix and prefills
            // only the non-shared tokens. `prefix_tokens == 0` jobs
            // take the legacy reservation arithmetic unchanged.
            let prefix_warm =
                head.prefix_tokens > 0 && self.prefixes.iter().any(|p| p.0 == head.prefix_id);
            let kv_need = if head.prefix_tokens == 0 {
                head.kv_bytes()
            } else if prefix_warm {
                head.suffix_kv_bytes()
            } else {
                head.prefix_kv_bytes() + head.suffix_kv_bytes()
            };
            if head.kv_bytes() > self.kv_budget {
                // Could never be admitted (a resident prefix is carved
                // from the same budget) — drop instead of wedging the
                // queue head forever.
                let job = self.queue.pop().unwrap();
                self.dropped += 1;
                events.push(BatchEvent::Dropped { job_id: job.job_id });
                continue;
            }
            if self.kv_used + kv_need > self.kv_budget {
                break;
            }
            let mut job = self.queue.pop().unwrap();
            if prefix_warm {
                // Only the non-shared suffix is prefilled; the charge
                // scales linearly with the remaining input tokens.
                job.prefill_time *= (job.n_input - job.prefix_tokens) as f64 / job.n_input as f64;
            }
            if self.discipline.drops_hopeless()
                && now + job.min_service_time() > job.deadline
            {
                self.dropped += 1;
                events.push(BatchEvent::Dropped { job_id: job.job_id });
                continue;
            }
            // Every += below is matched by a later -= of the *same*
            // stored f64 (job `kv_reserved`, block bytes), so release
            // arithmetic mirrors reservation arithmetic exactly.
            let kv_reserved = if job.prefix_tokens == 0 {
                self.kv_used += kv_need;
                kv_need
            } else if prefix_warm {
                let p = self.prefixes.iter_mut().find(|p| p.0 == job.prefix_id).unwrap();
                p.2 += 1;
                self.kv_used += kv_need;
                kv_need
            } else {
                // Cold prefix: materialize the refcounted block; the
                // job itself owns only its private suffix, the block
                // owns the shared tokens.
                let (pb, sb) = (job.prefix_kv_bytes(), job.suffix_kv_bytes());
                self.prefixes.push((job.prefix_id, pb, 1));
                self.kv_used += pb;
                self.kv_used += sb;
                sb
            };
            events.push(BatchEvent::Admitted { job_id: job.job_id });
            self.active.push(Active {
                job,
                tokens_left: job.n_output,
                prefilled: false,
                kv_reserved,
            });
        }
        if self.active.is_empty() {
            return; // idle; the next enqueue restarts the engine
        }
        // One iteration: newly admitted prefills + one batched decode
        // step for everything already prefilled.
        let mut prefill = 0.0;
        let mut compute = 0.0;
        let mut weights = 0.0f64;
        let mut decoding = false;
        for a in &self.active {
            if a.prefilled {
                decoding = true;
                compute += a.job.c_llm;
                weights = weights.max(a.job.m_llm);
            } else {
                prefill += a.job.prefill_time;
            }
        }
        let decode_step = if decoding {
            (compute / self.gpu.comp_flops).max(weights / self.gpu.mem_bw)
        } else {
            0.0
        };
        self.running = true;
        events.push(BatchEvent::StepAt { at: now + prefill + decode_step });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::{ComputeJob, ComputeNode, NodeEvent};
    use crate::llm::{CostModel, GpuSpec, JobSpec};

    const KV_PER_TOKEN: f64 = 524_288.0; // ≈ Llama-7B FP16

    fn job(id: u64, t_gen: f64, deadline: f64, n_output: u32, gpu: &GpuSpec) -> BatchJob {
        let spec = JobSpec { n_output, ..JobSpec::table1() };
        let m = CostModel::new(*gpu);
        BatchJob {
            job_id: id,
            t_gen,
            t_comm: 0.0,
            deadline,
            n_input: spec.n_input,
            n_output,
            prefill_time: m.prefill_latency(&spec),
            decode_time: m.tokengen_latency(&spec),
            c_llm: spec.c_llm,
            m_llm: spec.m_llm,
            kv_bytes_per_token: KV_PER_TOKEN,
            prefix_id: 0,
            prefix_tokens: 0,
        }
    }

    /// Drive the engine over a list of (arrival_time, job) pairs until
    /// idle; returns (first_token, finish) absolute times per job id.
    fn run(
        engine: &mut BatchEngine,
        arrivals: &[(f64, BatchJob)],
    ) -> std::collections::BTreeMap<u64, (f64, f64)> {
        let mut out = std::collections::BTreeMap::new();
        let mut first = std::collections::BTreeMap::new();
        let mut events = Vec::new();
        let mut pending_step: Option<f64> = None;
        let mut arrivals = arrivals.to_vec();
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut ai = 0;
        loop {
            // next event: arrival or step, whichever first
            let next_arr = arrivals.get(ai).map(|a| a.0);
            let (now, is_arrival) = match (next_arr, pending_step) {
                (Some(a), Some(s)) if a <= s => (a, true),
                (_, Some(s)) => (s, false),
                (Some(a), None) => (a, true),
                (None, None) => break,
            };
            events.clear();
            if is_arrival {
                let (_, j) = arrivals[ai];
                ai += 1;
                engine.enqueue(j, now, &mut events);
            } else {
                pending_step = None;
                engine.step(now, &mut events);
            }
            for ev in &events {
                match *ev {
                    BatchEvent::StepAt { at } => pending_step = Some(at),
                    BatchEvent::FirstToken { job_id } => {
                        first.insert(job_id, now);
                    }
                    BatchEvent::Finished { job_id } => {
                        out.insert(job_id, (first[&job_id], now));
                    }
                    _ => {}
                }
            }
        }
        out
    }

    #[test]
    fn single_job_matches_roofline_timeline() {
        let gpu = GpuSpec::a100();
        let m = CostModel::new(gpu);
        let spec = JobSpec::table1();
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, 1e9);
        let times = run(&mut e, &[(0.0, job(0, 0.0, 1.0, 15, &gpu))]);
        let (first, finish) = times[&0];
        let tok = m.token_latency(&spec);
        assert!((first - (m.prefill_latency(&spec) + tok)).abs() < 1e-12, "ttft {first}");
        assert!((finish - m.total_latency(&spec)).abs() < 1e-9, "finish {finish}");
    }

    #[test]
    fn memory_bound_batch_amortizes_weight_stream() {
        // 8 identical jobs arriving together: decode steps stay
        // memory-bound, so the makespan is far below 8× sequential.
        let gpu = GpuSpec::a100();
        let m = CostModel::new(gpu);
        let seq = m.total_latency(&JobSpec::table1());
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, 1e9);
        let arrivals: Vec<(f64, BatchJob)> =
            (0..8).map(|i| (0.0, job(i, 0.0, 10.0, 15, &gpu))).collect();
        let times = run(&mut e, &arrivals);
        assert_eq!(times.len(), 8);
        let makespan = times.values().map(|&(_, f)| f).fold(0.0, f64::max);
        assert!(
            makespan < 3.0 * seq,
            "batched makespan {makespan} vs sequential {seq} per job"
        );
        // throughput strictly better than serving the 8 one by one
        assert!(makespan < 8.0 * seq * 0.5);
    }

    #[test]
    fn kv_budget_gates_admission() {
        let gpu = GpuSpec::a100();
        // Budget fits exactly one 30-token job's KV.
        let budget = 30.0 * KV_PER_TOKEN + 1.0;
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, budget);
        let mut events = Vec::new();
        e.enqueue(job(0, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        e.enqueue(job(1, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        assert_eq!(e.batch_len(), 1, "KV budget admits only one job");
        assert_eq!(e.queue_len(), 1);
        let times = run(
            &mut BatchEngine::new(Discipline::Fifo, gpu, 8, budget),
            &[(0.0, job(0, 0.0, 10.0, 15, &gpu)), (0.0, job(1, 0.0, 10.0, 15, &gpu))],
        );
        // serialized: job 1 finishes ≈ 2× single service
        let m = CostModel::new(gpu);
        let seq = m.total_latency(&JobSpec::table1());
        assert!((times[&1].1 - 2.0 * seq).abs() < 1e-6, "t1 = {}", times[&1].1);
    }

    #[test]
    fn oversized_kv_demand_is_dropped_not_wedged() {
        let gpu = GpuSpec::a100();
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, 5.0 * KV_PER_TOKEN);
        let mut events = Vec::new();
        // 30-token context cannot ever fit a 5-token budget
        e.enqueue(job(0, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        assert!(events.contains(&BatchEvent::Dropped { job_id: 0 }));
        assert_eq!(e.dropped, 1);
        // and the engine still serves a job that fits
        let ok = BatchJob { n_input: 2, n_output: 2, ..job(1, 0.0, 10.0, 2, &gpu) };
        events.clear();
        e.enqueue(ok, 0.0, &mut events);
        assert_eq!(e.batch_len(), 1);
    }

    #[test]
    fn hopeless_jobs_dropped_at_admission() {
        let gpu = GpuSpec::a100();
        let discipline = Discipline::DeadlinePriority { drop_hopeless: true };
        let mut e = BatchEngine::new(discipline, gpu, 1, 1e9);
        let mut events = Vec::new();
        // occupies the single slot for ~110 ms
        e.enqueue(job(0, 0.0, 1.0, 15, &gpu), 0.0, &mut events);
        // deadline 50 ms: hopeless once the slot frees
        e.enqueue(job(1, 0.0, 0.050, 15, &gpu), 0.001, &mut events);
        let times = run_from(&mut e, events.clone());
        assert!(times.contains_key(&0));
        assert!(!times.contains_key(&1), "hopeless job must not complete");
        assert_eq!(e.dropped, 1);
    }

    /// Continue driving an engine whose first events are already out.
    fn run_from(
        engine: &mut BatchEngine,
        initial: Vec<BatchEvent>,
    ) -> std::collections::BTreeMap<u64, (f64, f64)> {
        let mut out = std::collections::BTreeMap::new();
        let mut first = std::collections::BTreeMap::new();
        let mut pending: Option<f64> = initial.iter().find_map(|e| match e {
            BatchEvent::StepAt { at } => Some(*at),
            _ => None,
        });
        let mut events = Vec::new();
        while let Some(now) = pending {
            pending = None;
            events.clear();
            engine.step(now, &mut events);
            for ev in &events {
                match *ev {
                    BatchEvent::StepAt { at } => pending = Some(at),
                    BatchEvent::FirstToken { job_id } => {
                        first.insert(job_id, now);
                    }
                    BatchEvent::Finished { job_id } => {
                        out.insert(job_id, (first[&job_id], now));
                    }
                    _ => {}
                }
            }
        }
        out
    }

    #[test]
    fn eviction_returns_batch_then_queue_and_resets_reservations() {
        let gpu = GpuSpec::a100();
        // budget fits two jobs' KV; the third waits in the queue
        let budget = 60.0 * KV_PER_TOKEN + 1.0;
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, budget);
        let mut events = Vec::new();
        e.enqueue(job(0, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        e.enqueue(job(1, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        e.enqueue(job(2, 0.0, 10.0, 15, &gpu), 0.0, &mut events);
        assert_eq!(e.batch_len(), 2);
        assert_eq!(e.queue_len(), 1);
        assert!(e.kv_used() > 0.0);
        assert!(!e.is_idle());
        let mut evicted = Vec::new();
        e.evict(&mut evicted);
        let ids: Vec<u64> = evicted.iter().map(|j| j.job_id).collect();
        assert_eq!(ids, vec![0, 1, 2], "admitted jobs first (id order), then queued");
        assert_eq!(e.batch_len(), 0);
        assert_eq!(e.queue_len(), 0);
        assert_eq!(e.kv_used(), 0.0);
        assert!(e.is_idle());
        // the engine restarts cleanly on the next enqueue
        events.clear();
        e.enqueue(job(3, 1.0, 10.0, 15, &gpu), 1.0, &mut events);
        assert!(events.iter().any(|ev| matches!(ev, BatchEvent::Admitted { job_id: 3 })));
        assert!(events.iter().any(|ev| matches!(ev, BatchEvent::StepAt { .. })));
    }

    #[test]
    fn max_batch_one_matches_sequential_node() {
        // Same arrivals through a 1-slot engine and a 1-server
        // sequential node: identical completion times (within f64
        // accumulation noise).
        let gpu = GpuSpec::gh200_nvl2();
        let arrivals: Vec<(f64, BatchJob)> = (0..4)
            .map(|i| (0.002 * i as f64, job(i as u64, 0.002 * i as f64, 1.0, 5 + i, &gpu)))
            .collect();
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 1, 1e12);
        let batch_times = run(&mut e, &arrivals);

        let mut node = ComputeNode::new(Discipline::Fifo, 1);
        let mut done: std::collections::BTreeMap<u64, f64> = Default::default();
        let mut ev: Vec<NodeEvent> = Vec::new();
        let mut pending: Vec<(f64, u64)> = Vec::new(); // (completes_at, id)
        let record = |ev: &[NodeEvent], pending: &mut Vec<(f64, u64)>| {
            for e in ev {
                if let NodeEvent::Started { job, completes_at } = e {
                    pending.push((*completes_at, job.job_id));
                }
            }
        };
        for (t, bj) in &arrivals {
            // finish anything due before this arrival
            pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            while let Some(&(ct, id)) = pending.first() {
                if ct > *t {
                    break;
                }
                pending.remove(0);
                done.insert(id, ct);
                ev.clear();
                node.complete(ct, &mut ev);
                record(&ev, &mut pending);
            }
            let cj = ComputeJob {
                job_id: bj.job_id,
                t_gen: bj.t_gen,
                t_comm: bj.t_comm,
                deadline: bj.deadline,
                service_time: bj.prefill_time + bj.decode_time,
            };
            ev.clear();
            node.enqueue(cj, *t, &mut ev);
            record(&ev, &mut pending);
        }
        while !pending.is_empty() {
            pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let (ct, id) = pending.remove(0);
            done.insert(id, ct);
            ev.clear();
            node.complete(ct, &mut ev);
            record(&ev, &mut pending);
        }
        assert_eq!(batch_times.len(), done.len());
        for (id, &(_, finish)) in &batch_times {
            let seq_finish = done[id];
            assert!(
                (finish - seq_finish).abs() < 1e-9,
                "job {id}: batch {finish} vs sequential {seq_finish}"
            );
        }
    }

    /// Like `run`, but also report the peak KV reservation observed
    /// across every engine interaction.
    fn run_peak(
        engine: &mut BatchEngine,
        arrivals: &[(f64, BatchJob)],
    ) -> (std::collections::BTreeMap<u64, (f64, f64)>, f64) {
        let mut out = std::collections::BTreeMap::new();
        let mut first = std::collections::BTreeMap::new();
        let mut events = Vec::new();
        let mut pending_step: Option<f64> = None;
        let mut arrivals = arrivals.to_vec();
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut ai = 0;
        let mut peak = 0.0f64;
        loop {
            let next_arr = arrivals.get(ai).map(|a| a.0);
            let (now, is_arrival) = match (next_arr, pending_step) {
                (Some(a), Some(s)) if a <= s => (a, true),
                (_, Some(s)) => (s, false),
                (Some(a), None) => (a, true),
                (None, None) => break,
            };
            events.clear();
            if is_arrival {
                let (_, j) = arrivals[ai];
                ai += 1;
                engine.enqueue(j, now, &mut events);
            } else {
                pending_step = None;
                engine.step(now, &mut events);
            }
            peak = peak.max(engine.kv_used());
            for ev in &events {
                match *ev {
                    BatchEvent::StepAt { at } => pending_step = Some(at),
                    BatchEvent::FirstToken { job_id } => {
                        first.insert(job_id, now);
                    }
                    BatchEvent::Finished { job_id } => {
                        out.insert(job_id, (first[&job_id], now));
                    }
                    _ => {}
                }
            }
        }
        (out, peak)
    }

    #[test]
    fn prefix_refcount_frees_only_on_last_release() {
        let gpu = GpuSpec::a100();
        let mut e = BatchEngine::new(Discipline::Fifo, gpu, 8, 1e9);
        let mut events = Vec::new();
        // job 0 decodes 2 tokens, job 1 decodes 15 → job 0 leaves the
        // batch first and must not tear down the shared block.
        let a = BatchJob { prefix_id: 7, prefix_tokens: 20, ..job(0, 0.0, 10.0, 2, &gpu) };
        let b = BatchJob { prefix_id: 7, prefix_tokens: 20, ..job(1, 0.0, 10.0, 15, &gpu) };
        e.enqueue(a, 0.0, &mut events);
        // job 0 admitted cold: block + private suffix reserved
        assert_eq!(e.prefix_refs(7), 1);
        assert_eq!(e.kv_used(), a.prefix_kv_bytes() + a.suffix_kv_bytes());
        e.enqueue(b, 0.0, &mut events);
        let mut pending: Option<f64> = events.iter().find_map(|ev| match ev {
            BatchEvent::StepAt { at } => Some(*at),
            _ => None,
        });
        let mut max_refs = e.prefix_refs(7);
        let mut peak_kv = e.kv_used();
        let mut saw_first_release = false;
        while let Some(now) = pending {
            events.clear();
            e.step(now, &mut events);
            pending = events.iter().find_map(|ev| match ev {
                BatchEvent::StepAt { at } => Some(*at),
                _ => None,
            });
            max_refs = max_refs.max(e.prefix_refs(7));
            peak_kv = peak_kv.max(e.kv_used());
            if events.iter().any(|ev| matches!(ev, BatchEvent::Finished { job_id: 0 })) {
                saw_first_release = true;
                assert!(e.prefix_resident(7), "live prefix must survive a release");
                assert_eq!(e.prefix_refs(7), 1);
                assert!(e.kv_used() > 0.0);
            }
        }
        assert!(saw_first_release);
        assert_eq!(max_refs, 2, "second job re-references the warm block");
        // warm second job reserved only its suffix
        assert_eq!(peak_kv, a.prefix_kv_bytes() + a.suffix_kv_bytes() + b.suffix_kv_bytes());
        assert!(peak_kv < a.kv_bytes() + b.kv_bytes(), "reuse must reserve less");
        assert!(!e.prefix_resident(7), "last release frees the block");
        assert_eq!(e.prefix_refs(7), 0);
        assert_eq!(e.kv_used(), 0.0);
    }

    #[test]
    fn prefix_reuse_peak_kv_and_makespan_never_exceed_no_reuse() {
        let gpu = GpuSpec::a100();
        let mk = |id: u64, pfx: u32| BatchJob {
            prefix_id: 3,
            prefix_tokens: pfx,
            ..job(id, 0.0, 10.0, 15, &gpu)
        };
        let shared: Vec<(f64, BatchJob)> =
            (0..6).map(|i| (0.001 * i as f64, mk(i as u64, 20))).collect();
        let raw: Vec<(f64, BatchJob)> =
            (0..6).map(|i| (0.001 * i as f64, mk(i as u64, 0))).collect();
        let (t_with, peak_with) =
            run_peak(&mut BatchEngine::new(Discipline::Fifo, gpu, 8, 1e9), &shared);
        let (t_without, peak_without) =
            run_peak(&mut BatchEngine::new(Discipline::Fifo, gpu, 8, 1e9), &raw);
        assert_eq!(t_with.len(), 6);
        assert_eq!(t_without.len(), 6);
        assert!(peak_with < peak_without, "peak {peak_with} vs {peak_without}");
        let ms = |t: &std::collections::BTreeMap<u64, (f64, f64)>| {
            t.values().map(|&(_, f)| f).fold(0.0, f64::max)
        };
        // shared prefills shrink the iterations, so the whole run ends
        // sooner too
        assert!(ms(&t_with) < ms(&t_without), "makespan {} vs {}", ms(&t_with), ms(&t_without));
    }

    #[test]
    fn prefix_reuse_admits_more_under_tight_budget() {
        let gpu = GpuSpec::a100();
        // Table-1 jobs: 45-token full context, 25-token suffix after a
        // 20-token shared prefix. Budget 100 tokens → without reuse
        // two jobs fit (90); with reuse three do (45 + 25 + 25 = 95).
        let budget = 100.0 * KV_PER_TOKEN;
        let mut with = BatchEngine::new(Discipline::Fifo, gpu, 8, budget);
        let mut without = BatchEngine::new(Discipline::Fifo, gpu, 8, budget);
        let mut ev_with = Vec::new();
        let mut ev_without = Vec::new();
        for i in 0..3u64 {
            let pj = BatchJob { prefix_id: 1, prefix_tokens: 20, ..job(i, 0.0, 10.0, 15, &gpu) };
            with.enqueue(pj, 0.0, &mut ev_with);
            without.enqueue(job(i, 0.0, 10.0, 15, &gpu), 0.0, &mut ev_without);
        }
        // Admission happens at iteration boundaries: drive one step on
        // each engine so the queued jobs get their admission pass.
        let at = |evs: &[BatchEvent]| {
            evs.iter()
                .find_map(|ev| match ev {
                    BatchEvent::StepAt { at } => Some(*at),
                    _ => None,
                })
                .unwrap()
        };
        let (tw, two) = (at(&ev_with), at(&ev_without));
        ev_with.clear();
        ev_without.clear();
        with.step(tw, &mut ev_with);
        without.step(two, &mut ev_without);
        assert_eq!(with.batch_len(), 3, "prefix reuse fits a third job");
        assert_eq!(without.batch_len(), 2);
        assert_eq!(without.queue_len(), 1);
    }
}
