//! Computing-node queueing (paper §IV-B item 2) and the execution
//! models that serve it.
//!
//! Two execution models share this tier (see [`ExecutionModel`]):
//!
//! * [`ComputeNode`] — the legacy **sequential** model: each job
//!   occupies one server for its whole roofline service time.
//! * [`engine::BatchEngine`] — **iteration-level continuous batching**:
//!   prefills are admitted against a KV-cache budget and decode steps
//!   are batched, amortizing the weight stream (extension §IV).
//!
//! Both run the same two queue disciplines:
//!
//! * **FIFO** — the 5G-MEC baseline.
//! * **Deadline priority** — ICC's priority-based job queueing: jobs
//!   are ordered by `T_gen + b_total − T_comm` (the communication-aware
//!   effective deadline; a job that already burned much of its budget
//!   in the air interface is served earlier), and any job whose
//!   *expected completion* would exceed `T_gen + b_total` is dropped
//!   rather than wasting GPU time.
//!
//! The node is a passive state machine: the owning simulator drives it
//! with `enqueue`/`complete` and schedules the returned completion
//! events on its own calendar. The event-reporting API is drain-style
//! (caller-provided `&mut Vec`), keeping the per-event hot path
//! allocation-free (DESIGN.md §7).

pub mod engine;

pub use engine::{BatchEngine, BatchEvent, BatchJob, ExecutionModel};

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// A job as seen by the computing node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeJob {
    pub job_id: u64,
    /// Generation time at the UE.
    pub t_gen: f64,
    /// Observed communication latency (UE→BS, incl. uplink queueing).
    pub t_comm: f64,
    /// Absolute deadline `t_gen + b_total`.
    pub deadline: f64,
    /// Deterministic service time (roofline).
    pub service_time: f64,
}

impl ComputeJob {
    /// ICC priority key: `T_gen + b_total − T_comm` — smaller = serve
    /// earlier (paper §IV-B).
    pub fn priority_key(&self) -> f64 {
        self.deadline - self.t_comm
    }
}

/// Queue ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    Fifo,
    /// ICC deadline priority; `drop_hopeless` enables the paper's drop
    /// rule at service start.
    DeadlinePriority { drop_hopeless: bool },
}

impl Discipline {
    /// Does this discipline drop jobs that cannot meet their deadline?
    pub fn drops_hopeless(&self) -> bool {
        matches!(self, Discipline::DeadlinePriority { drop_hopeless: true })
    }
}

/// Heap entry for the priority discipline (min-heap on key).
#[derive(Debug)]
struct PrioEntry<J> {
    key: f64,
    seq: u64,
    job: J,
}

impl<J> PartialEq for PrioEntry<J> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
impl<J> Eq for PrioEntry<J> {}
impl<J> PartialOrd for PrioEntry<J> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<J> Ord for PrioEntry<J> {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .key
            .partial_cmp(&self.key)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Discipline-ordered waiting line shared by both execution models:
/// FIFO ring or min-heap on a caller-supplied priority key, with a
/// stable FIFO tiebreak among equal keys.
#[derive(Debug)]
pub(crate) struct ReadyQueue<J> {
    discipline: Discipline,
    fifo: VecDeque<J>,
    prio: BinaryHeap<PrioEntry<J>>,
    seq: u64,
}

impl<J> ReadyQueue<J> {
    pub fn new(discipline: Discipline) -> Self {
        Self {
            discipline,
            fifo: VecDeque::new(),
            prio: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.fifo.len() + self.prio.len()
    }

    pub fn push(&mut self, job: J, key: f64) {
        match self.discipline {
            Discipline::Fifo => self.fifo.push_back(job),
            Discipline::DeadlinePriority { .. } => {
                let seq = self.seq;
                self.seq += 1;
                self.prio.push(PrioEntry { key, seq, job });
            }
        }
    }

    /// Next job to serve, without removing it.
    pub fn peek(&self) -> Option<&J> {
        match self.discipline {
            Discipline::Fifo => self.fifo.front(),
            Discipline::DeadlinePriority { .. } => self.prio.peek().map(|e| &e.job),
        }
    }

    pub fn pop(&mut self) -> Option<J> {
        match self.discipline {
            Discipline::Fifo => self.fifo.pop_front(),
            Discipline::DeadlinePriority { .. } => self.prio.pop().map(|e| e.job),
        }
    }

    /// Drain every waiting job into `out`, in discipline order (the
    /// order they would have been served) — deterministic, so cluster
    /// re-dispatch after a node failure is reproducible.
    pub fn drain_into(&mut self, out: &mut Vec<J>) {
        while let Some(j) = self.pop() {
            out.push(j);
        }
    }
}

impl<J: Copy> ReadyQueue<J> {
    /// Engine-snapshot view: `(seq counter, entries)`, each entry a
    /// `(priority key, insertion seq, job)` triple. FIFO queues list
    /// jobs front-to-back with positional seqs; priority queues list
    /// entries in ascending insertion order, so a rebuild reproduces
    /// the exact future pop order (key order + stable FIFO tiebreak).
    pub(crate) fn snapshot_entries(&self) -> (u64, Vec<(f64, u64, J)>) {
        match self.discipline {
            Discipline::Fifo => (
                self.seq,
                self.fifo.iter().enumerate().map(|(i, j)| (0.0, i as u64, *j)).collect(),
            ),
            Discipline::DeadlinePriority { .. } => {
                let mut v: Vec<(f64, u64, J)> =
                    self.prio.iter().map(|e| (e.key, e.seq, e.job)).collect();
                v.sort_by_key(|&(_, seq, _)| seq);
                (self.seq, v)
            }
        }
    }

    /// Rebuild from [`ReadyQueue::snapshot_entries`] output.
    pub(crate) fn restore(
        discipline: Discipline,
        seq: u64,
        entries: Vec<(f64, u64, J)>,
    ) -> Self {
        let mut rq = Self::new(discipline);
        match discipline {
            Discipline::Fifo => rq.fifo.extend(entries.into_iter().map(|(_, _, j)| j)),
            Discipline::DeadlinePriority { .. } => {
                for (key, s, job) in entries {
                    rq.prio.push(PrioEntry { key, seq: s, job });
                }
            }
        }
        rq.seq = seq;
        rq
    }
}

/// What happened when the node accepted / finished a job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeEvent {
    /// Service began; completion fires at the given absolute time.
    Started { job: ComputeJob, completes_at: f64 },
    /// Job was dropped by the hopeless-deadline rule.
    Dropped { job: ComputeJob },
}

/// The sequential computing node ([`ExecutionModel::Sequential`]).
#[derive(Debug)]
pub struct ComputeNode {
    discipline: Discipline,
    /// Parallel servers (1 for a tensor-parallel-aggregated pool).
    n_servers: u32,
    busy: u32,
    queue: ReadyQueue<ComputeJob>,
    /// Running count of dropped jobs.
    pub dropped: u64,
}

impl ComputeNode {
    pub fn new(discipline: Discipline, n_servers: u32) -> Self {
        assert!(n_servers >= 1);
        Self {
            discipline,
            n_servers,
            busy: 0,
            queue: ReadyQueue::new(discipline),
            dropped: 0,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_servers(&self) -> u32 {
        self.busy
    }

    /// Try to start jobs on free servers at time `now`, applying the
    /// drop rule. Resulting events (possibly several drops followed by
    /// starts) are appended to `events`.
    fn dispatch(&mut self, now: f64, events: &mut Vec<NodeEvent>) {
        while self.busy < self.n_servers {
            let Some(job) = self.queue.pop() else { break };
            if self.discipline.drops_hopeless() && now + job.service_time > job.deadline {
                self.dropped += 1;
                events.push(NodeEvent::Dropped { job });
                continue;
            }
            self.busy += 1;
            events.push(NodeEvent::Started { job, completes_at: now + job.service_time });
        }
    }

    /// A job arrives at the node's queue at time `now`. Events are
    /// appended to the caller's buffer (clear it between calls).
    pub fn enqueue(&mut self, job: ComputeJob, now: f64, events: &mut Vec<NodeEvent>) {
        self.queue.push(job, job.priority_key());
        self.dispatch(now, events);
    }

    /// A server finished at time `now`; pull the next job(s) in.
    pub fn complete(&mut self, now: f64, events: &mut Vec<NodeEvent>) {
        assert!(self.busy > 0, "complete() with no busy server");
        self.busy -= 1;
        self.dispatch(now, events);
    }

    /// Nothing queued or in service (a draining node at this point can
    /// power off).
    pub fn is_idle(&self) -> bool {
        self.busy == 0 && self.queue.len() == 0
    }

    /// Node-loss eviction: drain every *queued* job into `out` (in
    /// discipline order) and release all servers. Jobs already in
    /// service are not stored here — their identities live in the
    /// caller's scheduled completion events, which the caller must
    /// invalidate and re-dispatch itself (the cluster layer does this
    /// with per-node event epochs).
    pub fn evict(&mut self, out: &mut Vec<ComputeJob>) {
        self.queue.drain_into(out);
        self.busy = 0;
    }

    /// Engine-snapshot view: `(busy servers, dropped count, queue)`.
    pub(crate) fn snapshot_state(&self) -> (u32, u64, (u64, Vec<(f64, u64, ComputeJob)>)) {
        (self.busy, self.dropped, self.queue.snapshot_entries())
    }

    /// Rebuild a node from [`ComputeNode::snapshot_state`] output.
    pub(crate) fn restore(
        discipline: Discipline,
        n_servers: u32,
        busy: u32,
        dropped: u64,
        queue_seq: u64,
        queue_entries: Vec<(f64, u64, ComputeJob)>,
    ) -> Self {
        Self {
            discipline,
            n_servers,
            busy,
            queue: ReadyQueue::restore(discipline, queue_seq, queue_entries),
            dropped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, t_gen: f64, t_comm: f64, deadline: f64, svc: f64) -> ComputeJob {
        ComputeJob { job_id: id, t_gen, t_comm, deadline, service_time: svc }
    }

    /// Test shim preserving the old allocating call shape.
    fn enq(n: &mut ComputeNode, j: ComputeJob, now: f64) -> Vec<NodeEvent> {
        let mut ev = Vec::new();
        n.enqueue(j, now, &mut ev);
        ev
    }

    fn fin(n: &mut ComputeNode, now: f64) -> Vec<NodeEvent> {
        let mut ev = Vec::new();
        n.complete(now, &mut ev);
        ev
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let mut n = ComputeNode::new(Discipline::Fifo, 1);
        let ev = enq(&mut n, job(1, 0.0, 0.01, 0.08, 0.02), 0.0);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 1));
        enq(&mut n, job(2, 0.0, 0.01, 0.08, 0.02), 0.001);
        enq(&mut n, job(3, 0.0, 0.01, 0.08, 0.02), 0.002);
        let ev = fin(&mut n, 0.02);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 2));
        let ev = fin(&mut n, 0.04);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 3));
    }

    #[test]
    fn priority_orders_by_effective_deadline() {
        let mut n = ComputeNode::new(
            Discipline::DeadlinePriority { drop_hopeless: false },
            1,
        );
        // occupy the server
        enq(&mut n, job(0, 0.0, 0.0, 1.0, 0.050), 0.0);
        // job 1: late deadline, tiny comm → key 0.20
        enq(&mut n, job(1, 0.12, 0.0, 0.20, 0.01), 0.01);
        // job 2: earlier effective deadline: key 0.15 - 0.04 = 0.11
        enq(&mut n, job(2, 0.07, 0.04, 0.15, 0.01), 0.02);
        let ev = fin(&mut n, 0.05);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 2));
        let ev = fin(&mut n, 0.06);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 1));
    }

    #[test]
    fn priority_uses_comm_latency() {
        // Same absolute deadline; the job that spent more time in the
        // air interface must be served first (paper's key).
        let mut n = ComputeNode::new(
            Discipline::DeadlinePriority { drop_hopeless: false },
            1,
        );
        enq(&mut n, job(0, 0.0, 0.0, 1.0, 0.05), 0.0);
        enq(&mut n, job(1, 0.0, 0.010, 0.08, 0.01), 0.01); // key 0.07
        enq(&mut n, job(2, 0.0, 0.030, 0.08, 0.01), 0.01); // key 0.05
        let ev = fin(&mut n, 0.05);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 2));
    }

    #[test]
    fn hopeless_jobs_dropped_at_dispatch() {
        let mut n = ComputeNode::new(
            Discipline::DeadlinePriority { drop_hopeless: true },
            1,
        );
        enq(&mut n, job(0, 0.0, 0.0, 1.0, 0.050), 0.0);
        // deadline 0.06, service 0.02, will dispatch at 0.05 → 0.07 > 0.06
        enq(&mut n, job(1, 0.0, 0.0, 0.060, 0.020), 0.01);
        enq(&mut n, job(2, 0.0, 0.0, 0.100, 0.020), 0.01);
        let ev = fin(&mut n, 0.05);
        assert_eq!(ev.len(), 2);
        assert!(matches!(ev[0], NodeEvent::Dropped { job: j } if j.job_id == 1));
        assert!(matches!(ev[1], NodeEvent::Started { job: j, .. } if j.job_id == 2));
        assert_eq!(n.dropped, 1);
    }

    #[test]
    fn fifo_never_drops() {
        let mut n = ComputeNode::new(Discipline::Fifo, 1);
        enq(&mut n, job(0, 0.0, 0.0, 0.01, 0.5), 0.0);
        enq(&mut n, job(1, 0.0, 0.0, 0.01, 0.5), 0.0);
        let ev = fin(&mut n, 0.5); // way past both deadlines
        assert!(matches!(ev[0], NodeEvent::Started { .. }));
        assert_eq!(n.dropped, 0);
    }

    #[test]
    fn multi_server_parallelism() {
        let mut n = ComputeNode::new(Discipline::Fifo, 2);
        let e1 = enq(&mut n, job(1, 0.0, 0.0, 1.0, 0.1), 0.0);
        let e2 = enq(&mut n, job(2, 0.0, 0.0, 1.0, 0.1), 0.0);
        assert!(matches!(e1[0], NodeEvent::Started { .. }));
        assert!(matches!(e2[0], NodeEvent::Started { .. }));
        assert_eq!(n.busy_servers(), 2);
        let e3 = enq(&mut n, job(3, 0.0, 0.0, 1.0, 0.1), 0.01);
        assert!(e3.is_empty(), "both servers busy → queued");
        assert_eq!(n.queue_len(), 1);
    }

    #[test]
    fn work_conservation() {
        // Server never idles while the queue is non-empty.
        let mut n = ComputeNode::new(Discipline::Fifo, 1);
        enq(&mut n, job(1, 0.0, 0.0, 1.0, 0.1), 0.0);
        for id in 2..10 {
            enq(&mut n, job(id, 0.0, 0.0, 1.0, 0.1), 0.0);
        }
        let mut t = 0.1;
        let mut completions = 1;
        let mut ev = Vec::new();
        loop {
            ev.clear();
            n.complete(t, &mut ev);
            if ev.is_empty() {
                break;
            }
            completions += 1;
            t += 0.1;
        }
        assert_eq!(completions, 9);
        assert_eq!(n.queue_len(), 0);
        assert_eq!(n.busy_servers(), 0);
    }

    #[test]
    fn fifo_ties_stable() {
        let mut n = ComputeNode::new(
            Discipline::DeadlinePriority { drop_hopeless: false },
            1,
        );
        enq(&mut n, job(0, 0.0, 0.0, 1.0, 0.05), 0.0);
        // identical keys → FIFO among equals (seq tiebreak)
        enq(&mut n, job(1, 0.0, 0.01, 0.08, 0.01), 0.01);
        enq(&mut n, job(2, 0.0, 0.01, 0.08, 0.01), 0.02);
        let ev = fin(&mut n, 0.05);
        assert!(matches!(ev[0], NodeEvent::Started { job: j, .. } if j.job_id == 1));
    }

    #[test]
    fn eviction_drains_queue_in_service_order_and_frees_servers() {
        let mut n = ComputeNode::new(
            Discipline::DeadlinePriority { drop_hopeless: false },
            1,
        );
        enq(&mut n, job(0, 0.0, 0.0, 1.0, 0.5), 0.0); // in service
        enq(&mut n, job(1, 0.0, 0.0, 0.9, 0.01), 0.01); // key 0.9
        enq(&mut n, job(2, 0.0, 0.0, 0.5, 0.01), 0.02); // key 0.5 → first
        assert!(!n.is_idle());
        let mut evicted = Vec::new();
        n.evict(&mut evicted);
        let ids: Vec<u64> = evicted.iter().map(|j| j.job_id).collect();
        assert_eq!(ids, vec![2, 1], "queued jobs drain in priority order");
        assert_eq!(n.queue_len(), 0);
        assert_eq!(n.busy_servers(), 0);
        assert!(n.is_idle());
        // the rebuilt-from-scratch semantics: new work starts cleanly
        let ev = enq(&mut n, job(3, 0.0, 0.0, 1.0, 0.1), 1.0);
        assert!(matches!(ev[0], NodeEvent::Started { .. }));
    }

    #[test]
    fn event_buffer_is_reusable_across_calls() {
        // The drain-style API appends; callers clear between calls and
        // the capacity is reused (no per-event allocation).
        let mut n = ComputeNode::new(Discipline::Fifo, 1);
        let mut ev = Vec::with_capacity(4);
        n.enqueue(job(1, 0.0, 0.0, 1.0, 0.1), 0.0, &mut ev);
        assert_eq!(ev.len(), 1);
        let cap = ev.capacity();
        ev.clear();
        n.enqueue(job(2, 0.0, 0.0, 1.0, 0.1), 0.0, &mut ev);
        assert!(ev.is_empty(), "server busy → no events");
        ev.clear();
        n.complete(0.1, &mut ev);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev.capacity(), cap, "buffer must be reused, not reallocated");
    }
}
