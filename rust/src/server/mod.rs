//! Live LLM serving: a TCP front-end over the PJRT engine with
//! ICC-style deadline-aware admission.
//!
//! Architecture (threads + channels; the offline registry has no
//! tokio — see DESIGN.md §3):
//!
//! ```text
//! TCP accept loop ──► connection threads ──► request channel
//!                                                │
//!                               inference thread (owns the Engine,
//!                               EDF or FIFO queue, hopeless-drop)
//!                                                │
//!                              per-request response channels
//! ```
//!
//! The PJRT engine stays confined to one thread (its handles wrap raw
//! pointers), exactly like a GPU worker process in a production
//! serving stack; connection handling scales out independently.
//!
//! Protocol (line-based, UTF-8):
//!   request : `GEN <n_tokens> <budget_ms> <prompt text>\n`
//!   response: `OK <e2e_ms> <queue_ms> <text>` | `DROPPED deadline` |
//!             `ERR <msg>`

use std::collections::BinaryHeap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{tokenizer, Engine};
use crate::util::args::{usage, Args, OptSpec};

/// Queue discipline of the inference thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePolicy {
    /// FIFO, never drops (5G-MEC-baseline behaviour).
    Fifo,
    /// Earliest-deadline-first + drop jobs that cannot finish in
    /// budget (the ICC priority scheme).
    DeadlinePriority,
}

impl ServePolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(Self::Fifo),
            "edf" | "priority" => Some(Self::DeadlinePriority),
            _ => None,
        }
    }
}

/// An inference request crossing the channel.
pub struct Request {
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
    /// Absolute deadline (server clock).
    pub deadline: Instant,
    pub enqueued: Instant,
    pub resp: mpsc::Sender<Response>,
}

/// The inference thread's answer.
#[derive(Debug, Clone)]
pub enum Response {
    Ok { tokens: Vec<i32>, queue_s: f64, infer_s: f64 },
    Dropped,
    Err(String),
}

struct HeapEntry {
    deadline: Instant,
    seq: u64,
    req: Request,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap on (deadline, seq)
        other
            .deadline
            .cmp(&self.deadline)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The inference loop: owns the engine, applies the queue policy.
/// Returns when the request channel closes.
pub fn inference_loop(
    engine: &Engine,
    rx: mpsc::Receiver<Request>,
    policy: ServePolicy,
) -> (u64, u64) {
    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut fifo: std::collections::VecDeque<Request> = Default::default();
    let mut seq = 0u64;
    let mut served = 0u64;
    let mut dropped = 0u64;
    // Measured per-token cost estimate for the hopeless-drop rule,
    // refreshed from real inferences (seed with a conservative guess).
    let mut est_per_token = 0.010f64;

    loop {
        // Fill the local queue: block only when idle.
        let idle = heap.is_empty() && fifo.is_empty();
        let next = if idle {
            match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break,
            }
        } else {
            match rx.try_recv() {
                Ok(r) => Some(r),
                Err(mpsc::TryRecvError::Empty) => None,
                Err(mpsc::TryRecvError::Disconnected) if idle => break,
                Err(mpsc::TryRecvError::Disconnected) => None,
            }
        };
        if let Some(req) = next {
            match policy {
                ServePolicy::Fifo => fifo.push_back(req),
                ServePolicy::DeadlinePriority => {
                    heap.push(HeapEntry { deadline: req.deadline, seq, req });
                    seq += 1;
                }
            }
            continue; // keep draining the channel before serving
        }

        let Some(req) = (match policy {
            ServePolicy::Fifo => fifo.pop_front(),
            ServePolicy::DeadlinePriority => heap.pop().map(|e| e.req),
        }) else {
            continue;
        };

        let now = Instant::now();
        if policy == ServePolicy::DeadlinePriority {
            let expected = est_per_token * (req.n_tokens + 2) as f64;
            let remaining = req.deadline.saturating_duration_since(now).as_secs_f64();
            if expected > remaining {
                dropped += 1;
                let _ = req.resp.send(Response::Dropped);
                continue;
            }
        }
        let queue_s = now.duration_since(req.enqueued).as_secs_f64();
        let t0 = Instant::now();
        match engine.generate(&req.prompt, req.n_tokens) {
            Ok((tokens, stats)) => {
                let infer_s = t0.elapsed().as_secs_f64();
                if stats.tokens_out > 0 {
                    est_per_token = 0.7 * est_per_token
                        + 0.3 * (infer_s / (stats.tokens_out + 1) as f64);
                }
                served += 1;
                let _ = req.resp.send(Response::Ok { tokens, queue_s, infer_s });
            }
            Err(e) => {
                let _ = req.resp.send(Response::Err(format!("{e:#}")));
            }
        }
    }
    (served, dropped)
}

/// Parse one protocol line into (n_tokens, budget_ms, prompt).
pub fn parse_request_line(line: &str) -> Result<(usize, f64, String)> {
    let mut parts = line.splitn(4, ' ');
    let verb = parts.next().unwrap_or("");
    if verb != "GEN" {
        anyhow::bail!("expected 'GEN', got '{verb}'");
    }
    let n: usize = parts
        .next()
        .context("missing n_tokens")?
        .parse()
        .context("bad n_tokens")?;
    let budget: f64 = parts
        .next()
        .context("missing budget_ms")?
        .parse()
        .context("bad budget_ms")?;
    let prompt = parts.next().unwrap_or("").to_string();
    if n == 0 || n > 256 {
        anyhow::bail!("n_tokens out of range");
    }
    Ok((n, budget, prompt))
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<Request>,
    max_seq: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let line_t = line.trim_end();
        if line_t.is_empty() {
            continue;
        }
        if line_t == "PING" {
            writeln!(stream, "PONG")?;
            continue;
        }
        let t_arrive = Instant::now();
        match parse_request_line(line_t) {
            Ok((n_tokens, budget_ms, prompt_text)) => {
                let mut prompt = tokenizer::encode(&prompt_text);
                prompt.truncate(max_seq.saturating_sub(n_tokens).max(1));
                let (rtx, rrx) = mpsc::channel();
                let req = Request {
                    prompt,
                    n_tokens,
                    deadline: t_arrive + std::time::Duration::from_secs_f64(budget_ms / 1e3),
                    enqueued: t_arrive,
                    resp: rtx,
                };
                if tx.send(req).is_err() {
                    writeln!(stream, "ERR server shutting down")?;
                    return Ok(());
                }
                match rrx.recv() {
                    Ok(Response::Ok { tokens, queue_s, .. }) => {
                        let e2e = t_arrive.elapsed().as_secs_f64();
                        writeln!(
                            stream,
                            "OK {:.1} {:.1} {}",
                            e2e * 1e3,
                            queue_s * 1e3,
                            tokenizer::decode(&tokens).replace('\n', " ")
                        )?;
                    }
                    Ok(Response::Dropped) => writeln!(stream, "DROPPED deadline")?,
                    Ok(Response::Err(e)) => writeln!(stream, "ERR {e}")?,
                    Err(_) => writeln!(stream, "ERR inference thread gone")?,
                }
            }
            Err(e) => writeln!(stream, "ERR {e}")?,
        }
    }
}

/// Spawn the accept loop on its own thread: each connection gets a
/// handler thread feeding the shared request channel. Returns the
/// accept thread's handle (runs until the listener errors/closes).
pub fn spawn_accept_loop(
    listener: TcpListener,
    tx: mpsc::Sender<Request>,
    max_seq: usize,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(conn, tx, max_seq);
            });
        }
    })
}

/// `icc6g serve` — run the TCP server until killed.
pub fn cli_serve(argv: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "port", help: "TCP port", takes_value: true, default: Some("7070") },
        OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: None },
        OptSpec { name: "policy", help: "fifo | edf", takes_value: true, default: Some("edf") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv.iter().cloned(), &specs)?;
    if args.flag("help") {
        print!("{}", usage("icc6g serve", "Serve the tiny Llama over TCP", &specs));
        return Ok(());
    }
    let port = args.get_u64("port")?.unwrap() as u16;
    let policy = ServePolicy::parse(args.get("policy").unwrap())
        .context("policy must be fifo|edf")?;
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Engine::default_artifacts_dir);

    let (tx, rx) = mpsc::channel::<Request>();
    let listener = TcpListener::bind(("127.0.0.1", port))
        .with_context(|| format!("binding 127.0.0.1:{port}"))?;
    log::info!("listening on 127.0.0.1:{port} (policy {policy:?})");

    // Accept loop in a separate thread; inference (engine owner) here.
    let max_seq_guess = 64usize; // clamped again in handle_conn per request
    spawn_accept_loop(listener, tx, max_seq_guess);

    let engine = Engine::load(&dir)?;
    log::info!("engine ready: {} params", engine.meta.n_params);
    let (served, dropped) = inference_loop(&engine, rx, policy);
    log::info!("server exit: served {served}, dropped {dropped}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_line_ok() {
        let (n, b, p) = parse_request_line("GEN 15 80 hello world").unwrap();
        assert_eq!(n, 15);
        assert_eq!(b, 80.0);
        assert_eq!(p, "hello world");
    }

    #[test]
    fn parse_request_line_empty_prompt() {
        let (n, _, p) = parse_request_line("GEN 5 100").unwrap();
        assert_eq!(n, 5);
        assert_eq!(p, "");
    }

    #[test]
    fn parse_request_line_rejects_garbage() {
        assert!(parse_request_line("PUT 1 2 x").is_err());
        assert!(parse_request_line("GEN x 2 y").is_err());
        assert!(parse_request_line("GEN 0 2 y").is_err());
        assert!(parse_request_line("GEN 999 2 y").is_err());
    }

    #[test]
    fn policy_parse() {
        assert_eq!(ServePolicy::parse("fifo"), Some(ServePolicy::Fifo));
        assert_eq!(ServePolicy::parse("EDF"), Some(ServePolicy::DeadlinePriority));
        assert_eq!(ServePolicy::parse("x"), None);
    }

    #[test]
    fn heap_orders_by_deadline() {
        let now = Instant::now();
        let mk = |ms: u64, seq: u64| {
            let (tx, _rx) = mpsc::channel();
            HeapEntry {
                deadline: now + std::time::Duration::from_millis(ms),
                seq,
                req: Request {
                    prompt: vec![1],
                    n_tokens: 1,
                    deadline: now + std::time::Duration::from_millis(ms),
                    enqueued: now,
                    resp: tx,
                },
            }
        };
        let mut h = BinaryHeap::new();
        h.push(mk(50, 0));
        h.push(mk(10, 1));
        h.push(mk(30, 2));
        assert_eq!(h.pop().unwrap().seq, 1);
        assert_eq!(h.pop().unwrap().seq, 2);
        assert_eq!(h.pop().unwrap().seq, 0);
    }
}
