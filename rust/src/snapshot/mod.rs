//! Versioned binary snapshot format for exact engine checkpointing.
//!
//! A snapshot is a self-describing byte blob:
//!
//! ```text
//! magic    8 bytes  b"ICC6GSNP"
//! version  u32 LE   format version (bumped on any layout change)
//! fprint   u64 LE   config fingerprint (structural hash of the scenario)
//! payload  ...      engine state, written with [`Enc`]
//! ```
//!
//! The payload layout is private to `scenario::engine`; this module owns
//! the framing (magic/version/fingerprint checks with clear errors) and
//! the primitive codec. Everything is fixed-width little-endian so a
//! snapshot round-trips byte-identically across platforms, and a
//! snapshot → restore → snapshot cycle is byte-stable.
//!
//! See DESIGN.md §13 for the captured-state inventory and the RNG
//! stream-position discipline that makes restores bit-identical.

use std::fmt;

/// Magic bytes at the head of every snapshot file.
pub const MAGIC: [u8; 8] = *b"ICC6GSNP";

/// Current snapshot format version. Bump on any payload layout change.
/// v2: model-zoo fields (job model id, batch prefix blocks and KV
/// reservations, warm flags, per-model in-flight counters).
/// v3: fluid-tier state (per-cell activities and activity integrals,
/// tick counter, per-node background load).
pub const VERSION: u32 = 3;

/// Why a snapshot blob was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapError {
    /// The blob does not start with the `ICC6GSNP` magic.
    BadMagic,
    /// The blob's format version differs from this build's [`VERSION`].
    VersionMismatch { found: u32, expected: u32 },
    /// The blob was written under a structurally different scenario
    /// config (different cells/nodes/classes/topology/...).
    FingerprintMismatch { found: u64, expected: u64 },
    /// The blob ended before the decoder finished (`what` names the
    /// field being read when the bytes ran out).
    Truncated { what: &'static str },
    /// A decoded value is outside its legal range.
    Corrupt { what: &'static str },
}

impl fmt::Display for SnapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapError::BadMagic => {
                write!(f, "not an icc6g snapshot (missing ICC6GSNP magic)")
            }
            SnapError::VersionMismatch { found, expected } => write!(
                f,
                "snapshot format version {found} is not supported (this build reads version {expected})"
            ),
            SnapError::FingerprintMismatch { found, expected } => write!(
                f,
                "snapshot was taken under a different scenario config \
                 (fingerprint {found:#018x}, this scenario is {expected:#018x}); \
                 snapshots only restore into a structurally identical scenario"
            ),
            SnapError::Truncated { what } => {
                write!(f, "snapshot is truncated (ran out of bytes reading {what})")
            }
            SnapError::Corrupt { what } => {
                write!(f, "snapshot is corrupt (illegal value for {what})")
            }
        }
    }
}

impl std::error::Error for SnapError {}

/// FNV-1a over a byte string — the config-fingerprint hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only little-endian encoder for snapshot payloads.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Self { buf: Vec::with_capacity(4096) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// f64 by bit pattern — NaNs and signed zeros round-trip exactly.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }

    pub fn rng_state(&mut self, st: &([u64; 4], Option<f64>)) {
        for w in st.0 {
            self.u64(w);
        }
        self.opt_f64(st.1);
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }
}

/// Cursor-based decoder over a snapshot payload. Every read returns
/// `Err(SnapError::Truncated)` instead of panicking when bytes run out.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// True when every byte has been consumed.
    pub fn is_empty(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], SnapError> {
        let end = self.pos.checked_add(n).ok_or(SnapError::Truncated { what })?;
        if end > self.buf.len() {
            return Err(SnapError::Truncated { what });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self, what: &'static str) -> Result<u8, SnapError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn bool(&mut self, what: &'static str) -> Result<bool, SnapError> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(SnapError::Corrupt { what }),
        }
    }

    pub fn u32(&mut self, what: &'static str) -> Result<u32, SnapError> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &'static str) -> Result<u64, SnapError> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn usize(&mut self, what: &'static str) -> Result<usize, SnapError> {
        let v = self.u64(what)?;
        usize::try_from(v).map_err(|_| SnapError::Corrupt { what })
    }

    /// A length prefix that will drive a `Vec` allocation: reject
    /// lengths that cannot possibly fit in the remaining bytes (each
    /// element is at least one byte), so a corrupt blob cannot trigger
    /// a huge allocation.
    pub fn len(&mut self, what: &'static str) -> Result<usize, SnapError> {
        let n = self.usize(what)?;
        if n > self.buf.len().saturating_sub(self.pos) {
            return Err(SnapError::Truncated { what });
        }
        Ok(n)
    }

    pub fn f64(&mut self, what: &'static str) -> Result<f64, SnapError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    pub fn opt_f64(&mut self, what: &'static str) -> Result<Option<f64>, SnapError> {
        if self.bool(what)? { Ok(Some(self.f64(what)?)) } else { Ok(None) }
    }

    pub fn rng_state(
        &mut self,
        what: &'static str,
    ) -> Result<([u64; 4], Option<f64>), SnapError> {
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = self.u64(what)?;
        }
        Ok((s, self.opt_f64(what)?))
    }

    pub fn bytes(&mut self, what: &'static str) -> Result<&'a [u8], SnapError> {
        let n = self.len(what)?;
        self.take(n, what)
    }

    pub fn f64s(&mut self, what: &'static str) -> Result<Vec<f64>, SnapError> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            v.push(self.f64(what)?);
        }
        Ok(v)
    }
}

/// Frame a payload: magic + version + fingerprint + payload bytes.
pub fn frame(fingerprint: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(MAGIC.len() + 12 + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Check the frame and return the payload slice. `expected_fingerprint`
/// is the restoring scenario's own fingerprint; a mismatch means the
/// snapshot came from a structurally different config.
pub fn unframe(blob: &[u8], expected_fingerprint: u64) -> Result<&[u8], SnapError> {
    let mut d = Dec::new(blob);
    let magic = d.take(MAGIC.len(), "magic")?;
    if magic != MAGIC {
        return Err(SnapError::BadMagic);
    }
    let version = d.u32("format version")?;
    if version != VERSION {
        return Err(SnapError::VersionMismatch { found: version, expected: VERSION });
    }
    let fprint = d.u64("config fingerprint")?;
    if fprint != expected_fingerprint {
        return Err(SnapError::FingerprintMismatch {
            found: fprint,
            expected: expected_fingerprint,
        });
    }
    Ok(&blob[d.pos..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.f64(-0.0);
        e.f64(f64::INFINITY);
        e.opt_f64(None);
        e.opt_f64(Some(1.5));
        e.rng_state(&([1, 2, 3, 4], Some(0.25)));
        e.bytes(b"hello");
        e.f64s(&[1.0, 2.5]);
        let buf = e.into_bytes();

        let mut d = Dec::new(&buf);
        assert_eq!(d.u8("a").unwrap(), 7);
        assert!(d.bool("b").unwrap());
        assert_eq!(d.u32("c").unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64("d").unwrap(), u64::MAX - 3);
        let z = d.f64("e").unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits());
        assert_eq!(d.f64("f").unwrap(), f64::INFINITY);
        assert_eq!(d.opt_f64("g").unwrap(), None);
        assert_eq!(d.opt_f64("h").unwrap(), Some(1.5));
        assert_eq!(d.rng_state("i").unwrap(), ([1, 2, 3, 4], Some(0.25)));
        assert_eq!(d.bytes("j").unwrap(), b"hello");
        assert_eq!(d.f64s("k").unwrap(), vec![1.0, 2.5]);
        assert!(d.is_empty());
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut e = Enc::new();
        e.u64(42);
        let buf = e.into_bytes();
        let mut d = Dec::new(&buf[..5]);
        assert_eq!(d.u64("field").unwrap_err(), SnapError::Truncated { what: "field" });
    }

    #[test]
    fn oversized_len_prefix_rejected() {
        let mut e = Enc::new();
        e.usize(1 << 40); // claims a petabyte of elements
        let buf = e.into_bytes();
        let mut d = Dec::new(&buf);
        assert!(matches!(d.len("list"), Err(SnapError::Truncated { .. })));
    }

    #[test]
    fn frame_checks() {
        let blob = frame(0x1234, b"payload");
        assert_eq!(unframe(&blob, 0x1234).unwrap(), b"payload");
        assert_eq!(
            unframe(&blob, 0x9999).unwrap_err(),
            SnapError::FingerprintMismatch { found: 0x1234, expected: 0x9999 }
        );
        let mut bad = blob.clone();
        bad[0] = b'X';
        assert_eq!(unframe(&bad, 0x1234).unwrap_err(), SnapError::BadMagic);
        let mut v2 = blob.clone();
        v2[8] = 99;
        assert_eq!(
            unframe(&v2, 0x1234).unwrap_err(),
            SnapError::VersionMismatch { found: 99, expected: VERSION }
        );
        assert_eq!(
            unframe(&blob[..10], 0x1234).unwrap_err(),
            SnapError::Truncated { what: "config fingerprint" }
        );
    }

    #[test]
    fn fnv1a_known_values() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        // Differing inputs diverge.
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
