//! The ICC coordinator — the paper's system contribution.
//!
//! Ties the evaluation together: runs schemes over the SLS, searches
//! service capacity (max prompt rate at ≥ α satisfaction, Fig 6) and
//! minimum compute capacity (min ×A100 at ≥ α satisfaction, Fig 7),
//! and exposes the scheme presets. The *serving* coordinator (live
//! request routing over the PJRT runtime) lives in [`crate::server`];
//! this module is the evaluation/orchestration brain shared by both.

use crate::config::{SchemeConfig, SimConfig};
use crate::llm::GpuSpec;
use crate::metrics::SimReport;
use crate::sim::run_scheme;
use crate::sweep::{replication_seeds, sweep_grid};

/// A point of a satisfaction-vs-load curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    /// Offered prompt rate (prompts/s) or capacity (×A100), per sweep.
    pub x: f64,
    pub satisfaction: f64,
    pub avg_comm_ms: f64,
    pub avg_comp_ms: f64,
    pub avg_tokens_per_sec: f64,
}

impl CurvePoint {
    pub fn from_report(x: f64, r: &SimReport) -> Self {
        Self {
            x,
            satisfaction: r.satisfaction_rate(),
            avg_comm_ms: r.comm.mean() * 1e3,
            avg_comp_ms: r.comp.mean() * 1e3,
            avg_tokens_per_sec: r.tokens_per_sec.mean(),
        }
    }
}

/// Sweep satisfaction over prompt arrival rates by scaling the number
/// of UEs (paper Fig 6: "each UE generates 1 prompt/s and we scale the
/// number of UEs"). `seeds` > 1 averages independent replications.
/// Serial; see [`sweep_arrival_rates_threaded`] for the parallel
/// variant (bit-identical reports).
pub fn sweep_arrival_rates(
    base: &SimConfig,
    scheme: &SchemeConfig,
    rates: &[f64],
    seeds: u32,
) -> Vec<CurvePoint> {
    sweep_arrival_rates_threaded(base, scheme, rates, seeds, 1)
}

/// [`sweep_arrival_rates`] over `threads` worker threads (0 = all
/// cores). Every (rate, seed) replication is independent; per-point
/// reports merge in seed order, so the thread count never changes the
/// numbers — only the wall clock.
pub fn sweep_arrival_rates_threaded(
    base: &SimConfig,
    scheme: &SchemeConfig,
    rates: &[f64],
    seeds: u32,
    threads: usize,
) -> Vec<CurvePoint> {
    let seed_list = replication_seeds(base.seed, seeds);
    sweep_grid(rates, &seed_list, threads, |rate, seed| {
        let mut cfg = base.clone();
        cfg.n_ues = (rate / cfg.job_traffic.rate_per_ue).round().max(1.0) as u32;
        run_scheme(&cfg, scheme.clone(), seed)
    })
    .into_iter()
    .map(|p| CurvePoint::from_report(p.x, &p.report))
    .collect()
}

/// Sweep satisfaction over compute capacity (×A100), fixed 60 UEs
/// (paper Fig 7). Serial; see [`sweep_gpu_capacity_threaded`].
pub fn sweep_gpu_capacity(
    base: &SimConfig,
    scheme: &SchemeConfig,
    capacities: &[f64],
    seeds: u32,
) -> Vec<CurvePoint> {
    sweep_gpu_capacity_threaded(base, scheme, capacities, seeds, 1)
}

/// [`sweep_gpu_capacity`] over `threads` worker threads (0 = all
/// cores); bit-identical to the serial sweep.
pub fn sweep_gpu_capacity_threaded(
    base: &SimConfig,
    scheme: &SchemeConfig,
    capacities: &[f64],
    seeds: u32,
    threads: usize,
) -> Vec<CurvePoint> {
    let seed_list = replication_seeds(base.seed, seeds);
    sweep_grid(capacities, &seed_list, threads, |cap, seed| {
        let mut cfg = base.clone();
        cfg.gpu = GpuSpec::a100().scaled(cap);
        cfg.n_gpus = 1; // aggregated tensor-parallel pool
        run_scheme(&cfg, scheme.clone(), seed)
    })
    .into_iter()
    .map(|p| CurvePoint::from_report(p.x, &p.report))
    .collect()
}

/// Service capacity from a swept curve: the largest x whose
/// satisfaction ≥ α, linearly interpolating the crossing between grid
/// points (NaN-free; returns 0 if the first point already misses α).
pub fn capacity_from_curve(points: &[CurvePoint], alpha: f64) -> f64 {
    let mut last_ok: Option<&CurvePoint> = None;
    for p in points {
        if p.satisfaction >= alpha {
            last_ok = Some(p);
        } else if let Some(prev) = last_ok {
            // interpolate the α crossing between prev and p
            let dy = prev.satisfaction - p.satisfaction;
            if dy <= 1e-12 {
                return prev.x;
            }
            let w = (prev.satisfaction - alpha) / dy;
            return prev.x + w * (p.x - prev.x);
        }
    }
    last_ok.map(|p| p.x).unwrap_or(0.0)
}

/// Minimum capacity (×A100) achieving α from a Fig 7-style sweep:
/// smallest x with satisfaction ≥ α (interpolated). `None` if never
/// reached.
pub fn min_capacity_from_curve(points: &[CurvePoint], alpha: f64) -> Option<f64> {
    let mut prev: Option<&CurvePoint> = None;
    for p in points {
        if p.satisfaction >= alpha {
            if let Some(q) = prev {
                if q.satisfaction < alpha {
                    let dy = p.satisfaction - q.satisfaction;
                    if dy > 1e-12 {
                        let w = (alpha - q.satisfaction) / dy;
                        return Some(q.x + w * (p.x - q.x));
                    }
                }
            }
            return Some(p.x);
        }
        prev = Some(p);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(x: f64, s: f64) -> CurvePoint {
        CurvePoint { x, satisfaction: s, avg_comm_ms: 0.0, avg_comp_ms: 0.0, avg_tokens_per_sec: 0.0 }
    }

    #[test]
    fn capacity_interpolates_crossing() {
        let pts = [pt(10.0, 1.0), pt(20.0, 0.99), pt(30.0, 0.90)];
        let c = capacity_from_curve(&pts, 0.95);
        // crossing between 20 (0.99) and 30 (0.90): 20 + 10·(0.04/0.09)
        assert!((c - (20.0 + 10.0 * 0.04 / 0.09)).abs() < 1e-9, "c = {c}");
    }

    #[test]
    fn capacity_all_above_returns_last() {
        let pts = [pt(10.0, 1.0), pt(20.0, 0.99)];
        assert_eq!(capacity_from_curve(&pts, 0.95), 20.0);
    }

    #[test]
    fn capacity_all_below_returns_zero() {
        let pts = [pt(10.0, 0.5), pt(20.0, 0.4)];
        assert_eq!(capacity_from_curve(&pts, 0.95), 0.0);
    }

    #[test]
    fn min_capacity_interpolates() {
        let pts = [pt(4.0, 0.5), pt(8.0, 0.93), pt(12.0, 0.97)];
        let c = min_capacity_from_curve(&pts, 0.95).unwrap();
        assert!((c - (8.0 + 4.0 * 0.02 / 0.04)).abs() < 1e-9, "c = {c}");
        assert_eq!(min_capacity_from_curve(&pts, 0.99), None);
        assert_eq!(min_capacity_from_curve(&pts, 0.4).unwrap(), 4.0);
    }

    // Integration-style checks of the real sweeps live in
    // rust/tests/integration_sim.rs (they need seconds, not micros).
}
