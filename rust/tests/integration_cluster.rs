//! Elastic-cluster integration tests: the control-plane invariants.
//!
//! 1. **Bit-identity off-switch** — a cluster-enabled run under the
//!    `Fixed` policy with default (never-failing) churn is
//!    *bit-for-bit* identical to the same scenario with the control
//!    plane disabled, across schemes, cell counts and thread counts
//!    (property test). Only the cost ledger may differ — by existing.
//! 2. **Determinism** — churn scenarios replay exactly per seed and
//!    are invariant to the worker-thread count.
//! 3. **Accounting** — node failures, re-dispatches, lost work and the
//!    cost/energy ledger all reconcile against the per-job outcomes.
//! 4. **Autoscaling** — a queue-depth policy under light load releases
//!    the high-index node and spends less on it than on node 0.

use icc6g::config::SchemeConfig;
use icc6g::metrics::{ClusterReport, JobFate};
use icc6g::prop_assert;
use icc6g::scenario::{
    AutoscalerKind, CellSpec, ClusterSpec, NodeChurnSpec, ScenarioBuilder, ScenarioResult,
    ServiceModelKind, WorkloadClass,
};
use icc6g::util::jsonmini::Value;
use icc6g::util::proptest::check;

fn gpu() -> icc6g::llm::GpuSpec {
    icc6g::llm::GpuSpec::gh200_nvl2().scaled(2.0)
}

fn scheme(i: usize) -> SchemeConfig {
    match i {
        0 => SchemeConfig::icc(),
        1 => SchemeConfig::disjoint_ran(),
        _ => SchemeConfig::mec(),
    }
}

/// The base scenario of the off-switch property: 2 identical nodes,
/// optionally wrapped in a `Fixed`-policy control plane whose nodes
/// never fail — the configuration that must change nothing.
fn base(si: usize, n_cells: usize, ues: u32, seed: u64, threads: usize, cluster: bool) -> ScenarioResult {
    let mut b = ScenarioBuilder::new()
        .scheme(scheme(si))
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation());
    if n_cells > 1 {
        b = b.cells(n_cells, CellSpec::new(ues));
    } else {
        b = b.n_ues(ues);
    }
    b = b.node(gpu(), 1).node(gpu(), 1);
    if cluster {
        b = b.cluster(ClusterSpec::default());
    }
    b.build().run()
}

fn assert_outcomes_identical(a: &ScenarioResult, b: &ScenarioResult) {
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.job_id, y.job_id);
        assert_eq!(x.cell_id, y.cell_id);
        assert_eq!(x.class_id, y.class_id);
        assert_eq!(x.t_gen.to_bits(), y.t_gen.to_bits());
        assert_eq!(x.t_comm.to_bits(), y.t_comm.to_bits());
        assert_eq!(x.t_queue.to_bits(), y.t_queue.to_bits());
        assert_eq!(x.t_service.to_bits(), y.t_service.to_bits());
        assert_eq!(x.ttft.to_bits(), y.ttft.to_bits());
        assert_eq!(x.tpot.to_bits(), y.tpot.to_bits());
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.fate, y.fate);
    }
}

fn assert_cluster_identical(a: &ClusterReport, b: &ClusterReport) {
    assert_eq!(a.nodes.len(), b.nodes.len());
    for (x, y) in a.nodes.iter().zip(&b.nodes) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.gpu, y.gpu);
        assert_eq!(x.up_seconds.to_bits(), y.up_seconds.to_bits());
        assert_eq!(x.gpu_seconds.to_bits(), y.gpu_seconds.to_bits());
        assert_eq!(x.joules.to_bits(), y.joules.to_bits());
        assert_eq!(x.dollars.to_bits(), y.dollars.to_bits());
        assert_eq!(x.served, y.served);
        assert_eq!(x.redispatched, y.redispatched);
        assert_eq!(x.lost, y.lost);
        assert_eq!(x.failures, y.failures);
    }
    assert_eq!(a.classes.len(), b.classes.len());
    for (x, y) in a.classes.iter().zip(&b.classes) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.gpu_seconds.to_bits(), y.gpu_seconds.to_bits());
        assert_eq!(x.joules.to_bits(), y.joules.to_bits());
        assert_eq!(x.dollars.to_bits(), y.dollars.to_bits());
        assert_eq!(x.redispatched, y.redispatched);
        assert_eq!(x.lost, y.lost);
    }
}

#[test]
fn fixed_policy_cluster_is_bit_identical_to_disabled() {
    // The off-switch property ISSUE 6 pins: enabling the control plane
    // with a no-op policy and never-failing nodes must not perturb a
    // single bit of any job outcome or report statistic. Event counts
    // are NOT compared — control ticks legitimately add calendar pops.
    check(6, |g| {
        let si = g.usize_range(0, 2);
        let n_cells = g.usize_range(1, 3);
        let ues = g.usize_range(4, 8) as u32;
        let seed = g.u64_below(1000);
        let threads = g.usize_range(1, 2);
        let off = base(si, n_cells, ues, seed, threads, false);
        let on = base(si, n_cells, ues, seed, threads, true);
        prop_assert!(
            off.outcomes.len() == on.outcomes.len(),
            "scheme {si}, {n_cells} cell(s), seed {seed}: {} jobs disabled vs {} enabled",
            off.outcomes.len(),
            on.outcomes.len()
        );
        for (x, y) in off.outcomes.iter().zip(&on.outcomes) {
            prop_assert!(
                x.job_id == y.job_id
                    && x.t_gen.to_bits() == y.t_gen.to_bits()
                    && x.t_comm.to_bits() == y.t_comm.to_bits()
                    && x.t_queue.to_bits() == y.t_queue.to_bits()
                    && x.t_service.to_bits() == y.t_service.to_bits()
                    && x.ttft.to_bits() == y.ttft.to_bits()
                    && x.tpot.to_bits() == y.tpot.to_bits()
                    && x.tokens == y.tokens
                    && x.fate == y.fate,
                "scheme {si}, seed {seed}: job diverged\n  disabled: {x:?}\n  enabled:  {y:?}"
            );
        }
        prop_assert!(
            off.report.n_satisfied == on.report.n_satisfied
                && off.report.n_dropped == on.report.n_dropped
                && off.report.n_lost == 0
                && on.report.n_lost == 0
                && off.report.e2e.mean().to_bits() == on.report.e2e.mean().to_bits(),
            "scheme {si}, seed {seed}: report statistics diverged"
        );
        // the only permitted difference: the enabled run carries a
        // cost ledger, the disabled run carries none
        prop_assert!(off.report.cluster.is_empty(), "disabled run grew a cluster section");
        prop_assert!(
            !on.report.cluster.is_empty() && on.report.cluster.total_dollars() > 0.0,
            "enabled run priced nothing"
        );
        Ok(())
    });
}

/// A hostile tier: both nodes fail on average every second and take
/// ~0.3 s to repair, one retry per job. Warmup 0 so the cost ledger
/// and the per-job outcomes cover the same population.
fn churned(seed: u64, threads: usize) -> ScenarioResult {
    let churn = NodeChurnSpec { mtbf: 1.0, mttr: 0.3, spinup: 0.1 };
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(6.0)
        .warmup(0.0)
        .seed(seed)
        .threads(threads)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cells(2, CellSpec::new(8))
        .node(gpu(), 1)
        .node_churn(churn)
        .node(gpu(), 1)
        .node_churn(churn)
        .cluster(ClusterSpec { retry_budget: 1, ..Default::default() })
        .build()
        .run()
}

#[test]
fn churn_runs_replay_exactly_per_seed() {
    let a = churned(11, 1);
    let b = churned(11, 1);
    assert_eq!(a.events, b.events);
    assert_outcomes_identical(&a, &b);
    assert_cluster_identical(&a.report.cluster, &b.report.cluster);
}

#[test]
fn churn_runs_are_invariant_to_thread_count() {
    let serial = churned(11, 1);
    for threads in [2usize, 4, 0] {
        let par = churned(11, threads);
        assert_eq!(serial.events, par.events, "threads = {threads}");
        assert_outcomes_identical(&serial, &par);
        assert_cluster_identical(&serial.report.cluster, &par.report.cluster);
    }
}

#[test]
fn churn_accounting_reconciles_with_job_fates() {
    let res = churned(11, 1);
    let cl = &res.report.cluster;
    assert!(!cl.is_empty());
    let failures: u64 = cl.nodes.iter().map(|n| n.failures).sum();
    assert!(failures > 0, "MTBF 1 s over a 6 s horizon never failed");
    for n in &cl.nodes {
        assert!(n.up_seconds > 0.0, "{}: no powered time", n.name);
        assert!(n.gpu_seconds > 0.0 && n.joules > 0.0 && n.dollars > 0.0);
        // powered time is bounded by the accounting window (horizon +
        // the 2 s drain tail)
        assert!(n.up_seconds <= 6.0 + 2.0 + 1e-9, "{}: {}", n.name, n.up_seconds);
    }
    // the ledger and the per-job fates describe the same population
    let completed = res.outcomes.iter().filter(|o| o.fate == JobFate::Completed).count() as u64;
    let lost = res.outcomes.iter().filter(|o| o.fate == JobFate::Lost).count() as u64;
    let served: u64 = cl.nodes.iter().map(|n| n.served).sum();
    let node_lost: u64 = cl.nodes.iter().map(|n| n.lost).sum();
    let class_lost: u64 = cl.classes.iter().map(|c| c.lost).sum();
    assert_eq!(served, completed);
    assert_eq!(node_lost, lost);
    assert_eq!(class_lost, lost);
    assert_eq!(res.report.n_lost, lost);
    let node_redisp: u64 = cl.nodes.iter().map(|n| n.redispatched).sum();
    let class_redisp: u64 = cl.classes.iter().map(|c| c.redispatched).sum();
    assert_eq!(node_redisp, class_redisp);
    assert!(
        node_redisp + node_lost > 0,
        "frequent failures under load evicted nothing"
    );
    assert!(cl.total_dollars() > 0.0 && cl.total_joules() > 0.0);
    assert!(cl.capacity_per_dollar(res.report.n_satisfied).is_finite());
}

#[test]
fn queue_depth_policy_releases_idle_capacity() {
    // Light load (4 UEs over 2 nodes) with a queue-depth policy: the
    // autoscaler must drain the high-index node and keep node 0 warm,
    // so node 1 accrues strictly less powered time and cost.
    let res = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(6.0)
        .warmup(0.0)
        .seed(3)
        .n_ues(4)
        .workload(WorkloadClass::chat())
        .node(gpu(), 1)
        .node(gpu(), 1)
        .cluster(ClusterSpec {
            policy: AutoscalerKind::QueueDepth { high: 8, low: 1 },
            min_nodes: 1,
            ..Default::default()
        })
        .build()
        .run();
    let cl = &res.report.cluster;
    assert_eq!(cl.nodes.len(), 2);
    assert!(
        cl.nodes[1].up_seconds < cl.nodes[0].up_seconds,
        "idle node 1 was never released: {} vs {}",
        cl.nodes[1].up_seconds,
        cl.nodes[0].up_seconds
    );
    assert!(cl.nodes[1].dollars < cl.nodes[0].dollars);
    // jobs still complete on the surviving capacity
    assert!(res.outcomes.iter().any(|o| o.fate == JobFate::Completed));
    assert_eq!(res.report.n_lost, 0, "scaling down must drain, not kill, jobs");
}

#[test]
fn cluster_section_round_trips_through_json() {
    let res = churned(11, 1);
    let v = Value::parse(&res.report.to_json()).expect("report JSON must parse");
    assert_eq!(v.get("n_lost").unwrap().as_f64().unwrap() as u64, res.report.n_lost);
    let cl = v.get("cluster").expect("cluster section missing");
    let want = &res.report.cluster;
    let dollars = cl.get("total_dollars").unwrap().as_f64().unwrap();
    assert!((dollars - want.total_dollars()).abs() < 1e-9);
    let joules = cl.get("total_joules").unwrap().as_f64().unwrap();
    assert!((joules - want.total_joules()).abs() < 1e-6 * want.total_joules().max(1.0));
    let nodes = cl.get("nodes").unwrap().as_arr().unwrap();
    assert_eq!(nodes.len(), want.nodes.len());
    for (slot, nr) in nodes.iter().zip(&want.nodes) {
        assert_eq!(slot.get("name").unwrap().as_str().unwrap(), nr.name);
        assert_eq!(slot.get("served").unwrap().as_f64().unwrap() as u64, nr.served);
        assert_eq!(slot.get("failures").unwrap().as_f64().unwrap() as u64, nr.failures);
    }
    let classes = cl.get("classes").unwrap().as_arr().unwrap();
    assert_eq!(classes.len(), want.classes.len());
}
