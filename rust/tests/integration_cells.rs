//! Multi-cell integration tests: the sharding invariants.
//!
//! 1. **Decomposition** — an N-cell scenario with identical per-cell
//!    configs, strict cell-affinity routing and one node per cell is
//!    *job-for-job* identical to N independent single-cell scenarios
//!    seeded with `cell_seed(master, k)` (property test).
//! 2. **Bit-identity** — stepping cells on worker threads never changes
//!    a single bit of the outcomes relative to the serial cell loop.
//! 3. **Accounting** — per-cell report slices sum to the overall totals
//!    and merge exactly across replications.

use icc6g::config::SchemeConfig;
use icc6g::metrics::JobFate;
use icc6g::prop_assert;
use icc6g::scenario::{
    cell_seed, CellSpec, HandoverSpec, MobilitySpec, RoutingPolicy, ScenarioBuilder,
    ScenarioResult, ServiceModelKind, TopologySpec, WorkloadClass,
};
use icc6g::util::proptest::check;

fn gpu() -> icc6g::llm::GpuSpec {
    icc6g::llm::GpuSpec::gh200_nvl2().scaled(2.0)
}

/// An N-cell scenario over N dedicated nodes with strict (never-spill)
/// cell affinity — the topology whose cells are fully independent.
fn sharded(n_cells: usize, ues_per_cell: u32, seed: u64, threads: usize) -> ScenarioResult {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation());
    for _ in 0..n_cells {
        b = b.cell(CellSpec::new(ues_per_cell)).node(gpu(), 1);
    }
    b.build().run()
}

fn single(ues: u32, seed: u64) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cell(CellSpec::new(ues))
        .node(gpu(), 1)
        .build()
        .run()
}

#[test]
fn n_cell_scenario_matches_independent_single_cell_runs_job_for_job() {
    check(4, |g| {
        let n_cells = g.usize_range(2, 3);
        let ues = g.usize_range(4, 8) as u32;
        let seed = g.u64_below(1000);
        let multi = sharded(n_cells, ues, seed, 1);
        for k in 0..n_cells {
            let lone = single(ues, cell_seed(seed, k));
            let mine: Vec<_> = multi
                .outcomes
                .iter()
                .filter(|o| o.cell_id as usize == k)
                .collect();
            prop_assert!(
                mine.len() == lone.outcomes.len(),
                "cell {k}: {} jobs in the sharded run vs {} standalone",
                mine.len(),
                lone.outcomes.len()
            );
            // Per-cell outcome order is arrival order in both runs, so
            // the sequences align index-for-index. Every latency
            // component must match to the bit.
            for (a, b) in mine.iter().zip(&lone.outcomes) {
                prop_assert!(
                    a.t_gen.to_bits() == b.t_gen.to_bits()
                        && a.t_comm.to_bits() == b.t_comm.to_bits()
                        && a.t_queue.to_bits() == b.t_queue.to_bits()
                        && a.t_service.to_bits() == b.t_service.to_bits()
                        && a.ttft.to_bits() == b.ttft.to_bits()
                        && a.tpot.to_bits() == b.tpot.to_bits()
                        && a.tokens == b.tokens
                        && a.class_id == b.class_id
                        && a.fate == b.fate,
                    "cell {k}: job diverged\n  sharded:    {a:?}\n  standalone: {b:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_cell_stepping_is_bit_identical_to_serial() {
    for threads in [2usize, 4, 0] {
        let serial = sharded(4, 6, 9, 1);
        let parallel = sharded(4, 6, 9, threads);
        assert_eq!(serial.events, parallel.events, "threads = {threads}");
        assert_eq!(
            serial.outcomes.len(),
            parallel.outcomes.len(),
            "threads = {threads}"
        );
        for (a, b) in serial.outcomes.iter().zip(&parallel.outcomes) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.cell_id, b.cell_id);
            assert_eq!(a.class_id, b.class_id);
            assert_eq!(a.t_gen.to_bits(), b.t_gen.to_bits());
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits());
            assert_eq!(a.t_queue.to_bits(), b.t_queue.to_bits());
            assert_eq!(a.t_service.to_bits(), b.t_service.to_bits());
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.fate, b.fate);
        }
        assert_eq!(
            serial.report.e2e.mean().to_bits(),
            parallel.report.e2e.mean().to_bits()
        );
        assert_eq!(serial.report.n_satisfied, parallel.report.n_satisfied);
    }
}

#[test]
fn threaded_stepping_also_matches_with_shared_nodes_and_spill() {
    // Same bit-identity claim under a contended tier: 3 cells over 2
    // nodes, finite spill threshold, so routing decisions interleave
    // cells on shared nodes.
    let mk = |threads: usize| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(3.0)
            .warmup(0.5)
            .seed(5)
            .threads(threads)
            .routing(RoutingPolicy::CellAffinity { spill_queue: 1 })
            .cells(3, CellSpec::new(8))
            .node(gpu(), 1)
            .node(gpu(), 1)
            .build()
            .run()
    };
    let serial = mk(1);
    let parallel = mk(3);
    assert_eq!(serial.events, parallel.events);
    assert_eq!(serial.report.n_jobs, parallel.report.n_jobs);
    assert_eq!(
        serial.report.e2e.mean().to_bits(),
        parallel.report.e2e.mean().to_bits()
    );
    assert_eq!(
        serial.report.comm.mean().to_bits(),
        parallel.report.comm.mean().to_bits()
    );
}

/// A fully coupled-radio scenario: hex sites, geometry-driven
/// inter-cell interference, moving UEs, A3 handover, shared compute
/// tier with spill.
fn coupled(threads: usize, seed: u64) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(3.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .routing(RoutingPolicy::CellAffinity { spill_queue: 1 })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .cells(4, CellSpec::new(6))
        .topology(TopologySpec::hex(300.0))
        .mobility(MobilitySpec::fixed(30.0))
        .handover(HandoverSpec { hysteresis_db: 1.0, ttt_s: 0.1, interruption_slots: 4 })
        .node(gpu(), 1)
        .node(gpu(), 1)
        .build()
        .run()
}

#[test]
fn threaded_stepping_bit_identical_with_coupling_and_handover() {
    // The hardest determinism claim: with dynamic interference
    // coupling the cells AND handover migrating UEs between banks, the
    // worker-thread count still must not change a single bit — the
    // interference snapshot, the mobility tick and the migrations all
    // run serially between slot batches.
    let serial = coupled(1, 9);
    for threads in [2usize, 4, 0] {
        let par = coupled(threads, 9);
        assert_eq!(serial.events, par.events, "threads = {threads}");
        assert_eq!(serial.outcomes.len(), par.outcomes.len(), "threads = {threads}");
        for (a, b) in serial.outcomes.iter().zip(&par.outcomes) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.cell_id, b.cell_id);
            assert_eq!(a.t_gen.to_bits(), b.t_gen.to_bits());
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits());
            assert_eq!(a.t_queue.to_bits(), b.t_queue.to_bits());
            assert_eq!(a.t_service.to_bits(), b.t_service.to_bits());
            assert_eq!(a.fate, b.fate);
        }
        assert_eq!(
            serial.report.e2e.mean().to_bits(),
            par.report.e2e.mean().to_bits()
        );
        assert_eq!(serial.report.radio.len(), par.report.radio.len());
        for (a, b) in serial.report.radio.iter().zip(&par.report.radio) {
            assert_eq!(a.handovers_in, b.handovers_in, "threads = {threads}");
            assert_eq!(a.handovers_out, b.handovers_out, "threads = {threads}");
            assert_eq!(
                a.iot_db.mean().to_bits(),
                b.iot_db.mean().to_bits(),
                "threads = {threads}"
            );
        }
    }
}

#[test]
fn handover_conserves_ues_and_interference_is_observed() {
    let res = coupled(1, 21);
    assert_eq!(res.report.radio.len(), 4);
    // every migration out of one cell lands in another
    let ho_out: u64 = res.report.radio.iter().map(|r| r.handovers_out).sum();
    let ho_in: u64 = res.report.radio.iter().map(|r| r.handovers_in).sum();
    assert_eq!(ho_out, ho_in, "migrations must conserve UEs across banks");
    // 24 UEs moving at 30 m/s across 300 m sites with 1 dB hysteresis:
    // some A3 events must fire
    assert!(ho_out > 0, "expected at least one handover in the coupled run");
    // neighbor activity must have raised the interference floor at
    // least once somewhere
    let max_iot = res
        .report
        .radio
        .iter()
        .map(|r| r.iot_db.max())
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(max_iot > 0.0, "coupled cells never observed interference");
    // jobs still complete and per-cell accounting stays exact
    assert!(res.report.n_jobs > 0);
    let sum: u64 = res.report.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, res.report.n_jobs);
    assert!(
        res.outcomes.iter().any(|o| o.fate == JobFate::Completed),
        "no job completed under coupling"
    );
}

#[test]
fn per_cell_slices_sum_and_merge_across_replications() {
    let a = sharded(3, 6, 21, 1);
    assert_eq!(a.report.per_cell.len(), 3);
    let sum: u64 = a.report.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, a.report.n_jobs);
    for (k, c) in a.report.per_cell.iter().enumerate() {
        assert_eq!(c.name, format!("cell{k}"));
        assert!(c.n_jobs > 0, "cell {k} generated no jobs");
    }
    // replications with the same topology merge slice-wise
    let mut merged = a.report.clone();
    let b = sharded(3, 6, 22, 1);
    merged.merge(&b.report);
    assert_eq!(merged.per_cell.len(), 3);
    for k in 0..3 {
        assert_eq!(
            merged.per_cell[k].n_jobs,
            a.report.per_cell[k].n_jobs + b.report.per_cell[k].n_jobs
        );
    }
    let sum: u64 = merged.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, merged.n_jobs);
    // a different topology clears the breakdown rather than lying
    let mut mismatched = a.report.clone();
    mismatched.merge(&sharded(2, 6, 23, 1).report);
    assert!(mismatched.per_cell.is_empty());
}

#[test]
fn single_cell_runs_have_no_per_cell_slices_and_default_cell_matches_base() {
    let res = single(10, 3);
    assert!(res.report.per_cell.is_empty());
    // the legacy builder path (no explicit cell) is the same scenario
    let legacy = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(3)
        .n_ues(10)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .node(gpu(), 1)
        .build()
        .run();
    assert_eq!(res.report.n_jobs, legacy.report.n_jobs);
    assert_eq!(
        res.report.e2e.mean().to_bits(),
        legacy.report.e2e.mean().to_bits()
    );
}

#[test]
fn mixed_numerology_cells_coexist_in_one_scenario() {
    // One 60 kHz cell and one 30 kHz cell share the tier: slot clocks
    // differ, jobs still complete in both cells, runs are
    // deterministic.
    let mk = |threads: usize| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(3.0)
            .warmup(0.5)
            .seed(13)
            .threads(threads)
            .cell(CellSpec::new(8))
            .cell(CellSpec::new(8).with_numerology(1))
            .node(gpu(), 1)
            .node(gpu(), 1)
            .build()
            .run()
    };
    let res = mk(1);
    assert_eq!(res.report.per_cell.len(), 2);
    for c in &res.report.per_cell {
        assert!(c.n_jobs > 0, "cell '{}' generated no jobs", c.name);
    }
    let completed = res
        .outcomes
        .iter()
        .filter(|o| o.fate == JobFate::Completed)
        .count();
    assert!(completed > 0);
    // threaded run of mixed numerologies stays bit-identical too
    let par = mk(2);
    assert_eq!(res.events, par.events);
    assert_eq!(
        res.report.e2e.mean().to_bits(),
        par.report.e2e.mean().to_bits()
    );
}
