//! Multi-cell integration tests: the sharding invariants.
//!
//! 1. **Decomposition** — an N-cell scenario with identical per-cell
//!    configs, strict cell-affinity routing and one node per cell is
//!    *job-for-job* identical to N independent single-cell scenarios
//!    seeded with `cell_seed(master, k)` (property test).
//! 2. **Bit-identity** — stepping cells on worker threads never changes
//!    a single bit of the outcomes relative to the serial cell loop.
//! 3. **Accounting** — per-cell report slices sum to the overall totals
//!    and merge exactly across replications.

use icc6g::config::SchemeConfig;
use icc6g::metrics::JobFate;
use icc6g::prop_assert;
use icc6g::scenario::{
    cell_seed, AutoscalerKind, CellSpec, CellSync, ClusterSpec, HandoverSpec,
    MobilitySpec, NodeChurnSpec, RoutingPolicy, ScenarioBuilder, ScenarioResult,
    ServiceModelKind, TopologySpec, WorkloadClass,
};
use icc6g::util::proptest::check;

fn gpu() -> icc6g::llm::GpuSpec {
    icc6g::llm::GpuSpec::gh200_nvl2().scaled(2.0)
}

/// An N-cell scenario over N dedicated nodes with strict (never-spill)
/// cell affinity — the topology whose cells are fully independent.
fn sharded(n_cells: usize, ues_per_cell: u32, seed: u64, threads: usize) -> ScenarioResult {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation());
    for _ in 0..n_cells {
        b = b.cell(CellSpec::new(ues_per_cell)).node(gpu(), 1);
    }
    b.build().run()
}

fn single(ues: u32, seed: u64) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cell(CellSpec::new(ues))
        .node(gpu(), 1)
        .build()
        .run()
}

#[test]
fn n_cell_scenario_matches_independent_single_cell_runs_job_for_job() {
    check(4, |g| {
        let n_cells = g.usize_range(2, 3);
        let ues = g.usize_range(4, 8) as u32;
        let seed = g.u64_below(1000);
        let multi = sharded(n_cells, ues, seed, 1);
        for k in 0..n_cells {
            let lone = single(ues, cell_seed(seed, k));
            let mine: Vec<_> = multi
                .outcomes
                .iter()
                .filter(|o| o.cell_id as usize == k)
                .collect();
            prop_assert!(
                mine.len() == lone.outcomes.len(),
                "cell {k}: {} jobs in the sharded run vs {} standalone",
                mine.len(),
                lone.outcomes.len()
            );
            // Per-cell outcome order is arrival order in both runs, so
            // the sequences align index-for-index. Every latency
            // component must match to the bit.
            for (a, b) in mine.iter().zip(&lone.outcomes) {
                prop_assert!(
                    a.t_gen.to_bits() == b.t_gen.to_bits()
                        && a.t_comm.to_bits() == b.t_comm.to_bits()
                        && a.t_queue.to_bits() == b.t_queue.to_bits()
                        && a.t_service.to_bits() == b.t_service.to_bits()
                        && a.ttft.to_bits() == b.ttft.to_bits()
                        && a.tpot.to_bits() == b.tpot.to_bits()
                        && a.tokens == b.tokens
                        && a.class_id == b.class_id
                        && a.fate == b.fate,
                    "cell {k}: job diverged\n  sharded:    {a:?}\n  standalone: {b:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_cell_stepping_is_bit_identical_to_serial() {
    for threads in [2usize, 4, 0] {
        let serial = sharded(4, 6, 9, 1);
        let parallel = sharded(4, 6, 9, threads);
        assert_eq!(serial.events, parallel.events, "threads = {threads}");
        assert_eq!(
            serial.outcomes.len(),
            parallel.outcomes.len(),
            "threads = {threads}"
        );
        for (a, b) in serial.outcomes.iter().zip(&parallel.outcomes) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.cell_id, b.cell_id);
            assert_eq!(a.class_id, b.class_id);
            assert_eq!(a.t_gen.to_bits(), b.t_gen.to_bits());
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits());
            assert_eq!(a.t_queue.to_bits(), b.t_queue.to_bits());
            assert_eq!(a.t_service.to_bits(), b.t_service.to_bits());
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.fate, b.fate);
        }
        assert_eq!(
            serial.report.e2e.mean().to_bits(),
            parallel.report.e2e.mean().to_bits()
        );
        assert_eq!(serial.report.n_satisfied, parallel.report.n_satisfied);
    }
}

#[test]
fn threaded_stepping_also_matches_with_shared_nodes_and_spill() {
    // Same bit-identity claim under a contended tier: 3 cells over 2
    // nodes, finite spill threshold, so routing decisions interleave
    // cells on shared nodes.
    let mk = |threads: usize| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(3.0)
            .warmup(0.5)
            .seed(5)
            .threads(threads)
            .routing(RoutingPolicy::CellAffinity { spill_queue: 1 })
            .cells(3, CellSpec::new(8))
            .node(gpu(), 1)
            .node(gpu(), 1)
            .build()
            .run()
    };
    let serial = mk(1);
    let parallel = mk(3);
    assert_eq!(serial.events, parallel.events);
    assert_eq!(serial.report.n_jobs, parallel.report.n_jobs);
    assert_eq!(
        serial.report.e2e.mean().to_bits(),
        parallel.report.e2e.mean().to_bits()
    );
    assert_eq!(
        serial.report.comm.mean().to_bits(),
        parallel.report.comm.mean().to_bits()
    );
}

/// A fully coupled-radio scenario: hex sites, geometry-driven
/// inter-cell interference, moving UEs, A3 handover, shared compute
/// tier with spill.
fn coupled(threads: usize, seed: u64) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(3.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .routing(RoutingPolicy::CellAffinity { spill_queue: 1 })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .cells(4, CellSpec::new(6))
        .topology(TopologySpec::hex(300.0))
        .mobility(MobilitySpec::fixed(30.0))
        .handover(HandoverSpec { hysteresis_db: 1.0, ttt_s: 0.1, interruption_slots: 4 })
        .node(gpu(), 1)
        .node(gpu(), 1)
        .build()
        .run()
}

#[test]
fn threaded_stepping_bit_identical_with_coupling_and_handover() {
    // The hardest determinism claim: with dynamic interference
    // coupling the cells AND handover migrating UEs between banks, the
    // worker-thread count still must not change a single bit — the
    // interference snapshot, the mobility tick and the migrations all
    // run serially between slot batches.
    let serial = coupled(1, 9);
    for threads in [2usize, 4, 0] {
        let par = coupled(threads, 9);
        assert_eq!(serial.events, par.events, "threads = {threads}");
        assert_eq!(serial.outcomes.len(), par.outcomes.len(), "threads = {threads}");
        for (a, b) in serial.outcomes.iter().zip(&par.outcomes) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.cell_id, b.cell_id);
            assert_eq!(a.t_gen.to_bits(), b.t_gen.to_bits());
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits());
            assert_eq!(a.t_queue.to_bits(), b.t_queue.to_bits());
            assert_eq!(a.t_service.to_bits(), b.t_service.to_bits());
            assert_eq!(a.fate, b.fate);
        }
        assert_eq!(
            serial.report.e2e.mean().to_bits(),
            par.report.e2e.mean().to_bits()
        );
        assert_eq!(serial.report.radio.len(), par.report.radio.len());
        for (a, b) in serial.report.radio.iter().zip(&par.report.radio) {
            assert_eq!(a.handovers_in, b.handovers_in, "threads = {threads}");
            assert_eq!(a.handovers_out, b.handovers_out, "threads = {threads}");
            assert_eq!(
                a.iot_db.mean().to_bits(),
                b.iot_db.mean().to_bits(),
                "threads = {threads}"
            );
        }
    }
}

/// Bit-level equality of two runs: event count, every per-job latency
/// component, and the per-cell radio slices.
fn assert_bit_identical(a: &ScenarioResult, b: &ScenarioResult, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{tag}: job counts diverged");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.job_id, y.job_id, "{tag}");
        assert_eq!(x.cell_id, y.cell_id, "{tag}");
        assert_eq!(x.class_id, y.class_id, "{tag}");
        assert_eq!(x.t_gen.to_bits(), y.t_gen.to_bits(), "{tag}");
        assert_eq!(x.t_comm.to_bits(), y.t_comm.to_bits(), "{tag}");
        assert_eq!(x.t_queue.to_bits(), y.t_queue.to_bits(), "{tag}");
        assert_eq!(x.t_service.to_bits(), y.t_service.to_bits(), "{tag}");
        assert_eq!(x.ttft.to_bits(), y.ttft.to_bits(), "{tag}");
        assert_eq!(x.fate, y.fate, "{tag}");
    }
    assert_eq!(
        a.report.e2e.mean().to_bits(),
        b.report.e2e.mean().to_bits(),
        "{tag}"
    );
    assert_eq!(a.report.radio.len(), b.report.radio.len(), "{tag}");
    for (x, y) in a.report.radio.iter().zip(&b.report.radio) {
        assert_eq!(x.handovers_in, y.handovers_in, "{tag}");
        assert_eq!(x.handovers_out, y.handovers_out, "{tag}");
        assert_eq!(x.iot_db.mean().to_bits(), y.iot_db.mean().to_bits(), "{tag}");
    }
}

/// The full-surface scenario the conservative-PDES determinism claim is
/// pinned on: dynamic interference coupling, mobility, A3 handover, AND
/// an elastic cluster with node churn re-dispatching work.
fn churned(threads: usize, seed: u64, sync: CellSync) -> ScenarioResult {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(3.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .cell_sync(sync)
        .routing(RoutingPolicy::CellAffinity { spill_queue: 1 })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .cells(4, CellSpec::new(6))
        .topology(TopologySpec::hex(300.0))
        .mobility(MobilitySpec::fixed(30.0))
        .handover(HandoverSpec { hysteresis_db: 1.0, ttt_s: 0.1, interruption_slots: 4 })
        .cluster(ClusterSpec {
            policy: AutoscalerKind::QueueDepth { high: 6, low: 1 },
            min_nodes: 1,
            retry_budget: 1,
            ..Default::default()
        })
        .node(gpu(), 1)
        .node_churn(NodeChurnSpec { mtbf: 1.0, mttr: 0.3, spinup: 0.1 })
        .node(gpu(), 1)
        .build()
        .run()
}

#[test]
fn frontier_pdes_bit_identical_to_serial_under_coupling_handover_and_churn() {
    // The tentpole determinism property: the conservative frontier
    // scheduler, with every dynamic surface enabled at once, matches
    // the serial engine bit for bit at every thread count.
    let serial = churned(1, 17, CellSync::Frontier);
    assert!(serial.report.n_jobs > 0);
    // CI's pdes-matrix job pins a single worker count per leg via
    // ICC6G_PDES_THREADS; a plain `cargo test` sweeps all of them.
    let counts: Vec<usize> = match std::env::var("ICC6G_PDES_THREADS") {
        Ok(v) => vec![v
            .parse()
            .expect("ICC6G_PDES_THREADS must be a worker count")],
        Err(_) => vec![2, 4, 8],
    };
    for threads in counts {
        let par = churned(threads, 17, CellSync::Frontier);
        assert_bit_identical(&serial, &par, &format!("frontier x{threads}"));
    }
    // ... and the legacy barrier pool lands on the same trajectory, so
    // the two threaded protocols are interchangeable A/B candidates.
    let barrier = churned(4, 17, CellSync::Barrier);
    assert_bit_identical(&serial, &barrier, "barrier x4");
}

#[test]
fn frontier_pdes_64_cell_smoke() {
    // Coupled 64-cell hex grid: the frontier structure must stay
    // correct (and bit-identical to serial) well past the thread count.
    let mk = |threads: usize| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(0.5)
            .warmup(0.1)
            .seed(3)
            .threads(threads)
            .service_kind(ServiceModelKind::TokenSampled)
            .workload(WorkloadClass::chat())
            .cells(64, CellSpec::new(2))
            .topology(TopologySpec::hex(300.0))
            .node(gpu().scaled(4.0), 2)
            .build()
            .run()
    };
    let serial = mk(1);
    assert_eq!(serial.report.radio.len(), 64);
    assert!(serial.report.n_jobs > 0);
    let par = mk(0); // all cores
    assert_bit_identical(&serial, &par, "64-cell frontier");
}

#[test]
fn correlated_shadowing_is_deterministic_and_thread_invariant() {
    let mk = |threads: usize, corr: Option<f64>| {
        let mut mob = MobilitySpec::fixed(30.0);
        if let Some(d) = corr {
            mob = mob.with_shadow_corr(d);
        }
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(2.0)
            .warmup(0.5)
            .seed(9)
            .threads(threads)
            .service_kind(ServiceModelKind::TokenSampled)
            .workload(WorkloadClass::chat())
            .cells(4, CellSpec::new(6))
            .topology(TopologySpec::hex(300.0))
            .mobility(mob)
            .handover(HandoverSpec {
                hysteresis_db: 1.0,
                ttt_s: 0.1,
                interruption_slots: 4,
            })
            .node(gpu(), 1)
            .node(gpu(), 1)
            .build()
            .run()
    };
    // Gudmundson decorrelation is deterministic per seed ...
    let corr = mk(1, Some(50.0));
    assert_bit_identical(&corr, &mk(1, Some(50.0)), "corr repeat");
    // ... invariant to the thread count ...
    assert_bit_identical(&corr, &mk(4, Some(50.0)), "corr x4");
    // ... and actually perturbs the radio trajectory relative to the
    // default drop-time shadowing (the off path draws nothing extra).
    let base = mk(1, None);
    let differs = base
        .report
        .radio
        .iter()
        .zip(&corr.report.radio)
        .any(|(a, b)| a.iot_db.mean().to_bits() != b.iot_db.mean().to_bits());
    assert!(
        differs || base.events != corr.events,
        "correlated shadowing changed nothing observable"
    );
}

#[test]
fn handover_conserves_ues_and_interference_is_observed() {
    let res = coupled(1, 21);
    assert_eq!(res.report.radio.len(), 4);
    // every migration out of one cell lands in another
    let ho_out: u64 = res.report.radio.iter().map(|r| r.handovers_out).sum();
    let ho_in: u64 = res.report.radio.iter().map(|r| r.handovers_in).sum();
    assert_eq!(ho_out, ho_in, "migrations must conserve UEs across banks");
    // 24 UEs moving at 30 m/s across 300 m sites with 1 dB hysteresis:
    // some A3 events must fire
    assert!(ho_out > 0, "expected at least one handover in the coupled run");
    // neighbor activity must have raised the interference floor at
    // least once somewhere
    let max_iot = res
        .report
        .radio
        .iter()
        .map(|r| r.iot_db.max())
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(max_iot > 0.0, "coupled cells never observed interference");
    // jobs still complete and per-cell accounting stays exact
    assert!(res.report.n_jobs > 0);
    let sum: u64 = res.report.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, res.report.n_jobs);
    assert!(
        res.outcomes.iter().any(|o| o.fate == JobFate::Completed),
        "no job completed under coupling"
    );
}

#[test]
fn per_cell_slices_sum_and_merge_across_replications() {
    let a = sharded(3, 6, 21, 1);
    assert_eq!(a.report.per_cell.len(), 3);
    let sum: u64 = a.report.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, a.report.n_jobs);
    for (k, c) in a.report.per_cell.iter().enumerate() {
        assert_eq!(c.name, format!("cell{k}"));
        assert!(c.n_jobs > 0, "cell {k} generated no jobs");
    }
    // replications with the same topology merge slice-wise
    let mut merged = a.report.clone();
    let b = sharded(3, 6, 22, 1);
    merged.merge(&b.report);
    assert_eq!(merged.per_cell.len(), 3);
    for k in 0..3 {
        assert_eq!(
            merged.per_cell[k].n_jobs,
            a.report.per_cell[k].n_jobs + b.report.per_cell[k].n_jobs
        );
    }
    let sum: u64 = merged.per_cell.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, merged.n_jobs);
    // a different topology clears the breakdown rather than lying
    let mut mismatched = a.report.clone();
    mismatched.merge(&sharded(2, 6, 23, 1).report);
    assert!(mismatched.per_cell.is_empty());
}

#[test]
fn single_cell_runs_have_no_per_cell_slices_and_default_cell_matches_base() {
    let res = single(10, 3);
    assert!(res.report.per_cell.is_empty());
    // the legacy builder path (no explicit cell) is the same scenario
    let legacy = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(3)
        .n_ues(10)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .node(gpu(), 1)
        .build()
        .run();
    assert_eq!(res.report.n_jobs, legacy.report.n_jobs);
    assert_eq!(
        res.report.e2e.mean().to_bits(),
        legacy.report.e2e.mean().to_bits()
    );
}

#[test]
fn mixed_numerology_cells_coexist_in_one_scenario() {
    // One 60 kHz cell and one 30 kHz cell share the tier: slot clocks
    // differ, jobs still complete in both cells, runs are
    // deterministic.
    let mk = |threads: usize| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(3.0)
            .warmup(0.5)
            .seed(13)
            .threads(threads)
            .cell(CellSpec::new(8))
            .cell(CellSpec::new(8).with_numerology(1))
            .node(gpu(), 1)
            .node(gpu(), 1)
            .build()
            .run()
    };
    let res = mk(1);
    assert_eq!(res.report.per_cell.len(), 2);
    for c in &res.report.per_cell {
        assert!(c.n_jobs > 0, "cell '{}' generated no jobs", c.name);
    }
    let completed = res
        .outcomes
        .iter()
        .filter(|o| o.fate == JobFate::Completed)
        .count();
    assert!(completed > 0);
    // threaded run of mixed numerologies stays bit-identical too
    let par = mk(2);
    assert_eq!(res.events, par.events);
    assert_eq!(
        res.report.e2e.mean().to_bits(),
        par.report.e2e.mean().to_bits()
    );
}
