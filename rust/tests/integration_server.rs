//! Serving-path integration: the inference loop's queue policies over
//! the real PJRT engine, and the TCP protocol plumbing.
//!
//! Self-skips when artifacts are absent.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use icc6g::runtime::{tokenizer, Engine};
use icc6g::server::{inference_loop, parse_request_line, Request, Response, ServePolicy};

fn load_engine() -> Option<Engine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("prefill.hlo.txt")
        .exists()
        .then(|| Engine::load(&dir).expect("engine must load"))
}

fn mk_request(
    text: &str,
    n_tokens: usize,
    budget: Duration,
) -> (Request, mpsc::Receiver<Response>) {
    let (tx, rx) = mpsc::channel();
    let now = Instant::now();
    (
        Request {
            prompt: tokenizer::encode(text),
            n_tokens,
            deadline: now + budget,
            enqueued: now,
            resp: tx,
        },
        rx,
    )
}

#[test]
fn fifo_serves_all_in_order() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel::<Request>();
    let mut receivers = Vec::new();
    for i in 0..4 {
        let (req, rrx) = mk_request(&format!("request {i}"), 3, Duration::from_secs(60));
        tx.send(req).unwrap();
        receivers.push(rrx);
    }
    drop(tx);
    let (served, dropped) = inference_loop(&engine, rx, ServePolicy::Fifo);
    assert_eq!(served, 4);
    assert_eq!(dropped, 0);
    for rrx in receivers {
        match rrx.recv().unwrap() {
            Response::Ok { tokens, .. } => assert_eq!(tokens.len(), 3),
            other => panic!("expected Ok, got {other:?}"),
        }
    }
}

#[test]
fn edf_drops_hopeless_requests() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (tx, rx) = mpsc::channel::<Request>();
    // An already-expired budget: must be dropped, not served.
    let (req, rrx_dead) = mk_request("expired", 5, Duration::from_millis(0));
    tx.send(req).unwrap();
    // A healthy request: must be served.
    let (req, rrx_ok) = mk_request("healthy", 3, Duration::from_secs(60));
    tx.send(req).unwrap();
    drop(tx);
    let (served, dropped) = inference_loop(&engine, rx, ServePolicy::DeadlinePriority);
    assert_eq!(served, 1, "healthy request must be served");
    assert_eq!(dropped, 1, "expired request must be dropped");
    assert!(matches!(rrx_dead.recv().unwrap(), Response::Dropped));
    assert!(matches!(rrx_ok.recv().unwrap(), Response::Ok { .. }));
}

#[test]
fn edf_orders_by_deadline_under_backlog() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Enqueue BEFORE starting the loop so the scheduler sees a backlog
    // and must pick the earliest deadline first.
    let (tx, rx) = mpsc::channel::<Request>();
    let (late, rrx_late) = mk_request("late deadline", 2, Duration::from_secs(120));
    let (soon, rrx_soon) = mk_request("soon deadline", 2, Duration::from_secs(30));
    tx.send(late).unwrap();
    tx.send(soon).unwrap();
    drop(tx);
    let (served, _) = inference_loop(&engine, rx, ServePolicy::DeadlinePriority);
    assert_eq!(served, 2);
    let t_soon = match rrx_soon.recv().unwrap() {
        Response::Ok { queue_s, .. } => queue_s,
        other => panic!("{other:?}"),
    };
    let t_late = match rrx_late.recv().unwrap() {
        Response::Ok { queue_s, .. } => queue_s,
        other => panic!("{other:?}"),
    };
    assert!(
        t_soon < t_late,
        "earliest deadline must leave the queue first ({t_soon} vs {t_late})"
    );
}

#[test]
fn protocol_roundtrip_parsing() {
    let (n, b, p) = parse_request_line("GEN 15 80 translate this sentence").unwrap();
    assert_eq!((n, b, p.as_str()), (15, 80.0, "translate this sentence"));
    assert!(parse_request_line("").is_err());
    assert!(parse_request_line("GEN").is_err());
}
