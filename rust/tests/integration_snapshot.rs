//! Snapshot/restore integration tests: the checkpointing invariants.
//!
//! 1. **Bit-identity** — `run_to(t) → snapshot → restore → run_to(∞)`
//!    reproduces an uninterrupted run bit for bit, across thread
//!    counts and both parallel cell schedulers, with every subsystem
//!    live (coupled radios, mobility, handover, node churn,
//!    continuous batching).
//! 2. **Robustness** — garbage, truncated, version-skewed, and
//!    wrong-config blobs are rejected with the specific [`SnapError`]
//!    each deserves, and a serialize → restore → serialize cycle is
//!    byte-stable.
//! 3. **Warm-start sweeps** — a warm sweep over a rate-invariant
//!    prefix merges to the *identical* per-point reports as the cold
//!    sweep ([`WarmStart::Exact`]).
//! 4. **Re-dispatch repricing** — a job re-dispatched to a different
//!    GPU tier runs at the destination roofline (DESIGN.md §11).
//! 5. **Rate-phase boundaries** — phases at the horizon, zero-rate
//!    phases, and single-phase schedules behave exactly as documented.

use icc6g::config::SchemeConfig;
use icc6g::llm::{CostModel, GpuSpec};
use icc6g::metrics::JobFate;
use icc6g::prop_assert;
use icc6g::scenario::{
    CellSpec, CellSync, ClusterSpec, ExecutionModel, HandoverSpec, MobilitySpec,
    NodeChurnSpec, RoutingPolicy, Scenario, ScenarioBuilder, ScenarioEngine,
    ScenarioResult, ServiceModelKind, SiteLayout, TokenDist, TopologySpec,
    WorkloadClass,
};
use icc6g::snapshot::{SnapError, MAGIC, VERSION};
use icc6g::sweep::{sweep_grid, sweep_grid_warm, WarmStart};
use icc6g::util::proptest::check;
use icc6g::util::tomlmini::Document;

fn gpu() -> GpuSpec {
    GpuSpec::gh200_nvl2().scaled(2.0)
}

/// Every subsystem at once: 3 coupled cells with moving UEs and A3
/// handover, a churning sequential node plus a continuous-batching
/// node behind the elastic control plane, token-sampled service.
/// The hardest state a snapshot has to capture.
fn rich(seed: u64, threads: usize, sync: CellSync) -> Scenario {
    let churn = NodeChurnSpec { mtbf: 1.5, mttr: 0.4, spinup: 0.1 };
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .cell_sync(sync)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cells(3, CellSpec::new(5))
        .topology(TopologySpec { layout: SiteLayout::Hex, isd_m: 200.0 })
        .mobility(MobilitySpec::fixed(30.0))
        .handover(HandoverSpec::default())
        .node(gpu(), 1)
        .node_churn(churn)
        .node_exec(gpu(), 1, ExecutionModel::ContinuousBatching {
            max_batch: 4,
            kv_budget: 0.0,
        })
        .cluster(ClusterSpec { retry_budget: 1, ..Default::default() })
        .build()
}

fn assert_results_identical(a: &ScenarioResult, b: &ScenarioResult, ctx: &str) {
    assert_eq!(a.events, b.events, "{ctx}: event counts diverged");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: job counts diverged");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert!(
            x.job_id == y.job_id
                && x.class_id == y.class_id
                && x.cell_id == y.cell_id
                && x.t_gen.to_bits() == y.t_gen.to_bits()
                && x.t_comm.to_bits() == y.t_comm.to_bits()
                && x.t_queue.to_bits() == y.t_queue.to_bits()
                && x.t_service.to_bits() == y.t_service.to_bits()
                && x.ttft.to_bits() == y.ttft.to_bits()
                && x.tpot.to_bits() == y.tpot.to_bits()
                && x.tokens == y.tokens
                && x.fate == y.fate,
            "{ctx}: job diverged\n  cold:    {x:?}\n  resumed: {y:?}"
        );
    }
    // The reports are pure functions of the outcomes plus the radio
    // and cluster sections — the JSON covers all of them.
    assert_eq!(a.report.to_json(), b.report.to_json(), "{ctx}: reports diverged");
}

/// Run `rich` uninterrupted, then again with a snapshot/restore cycle
/// at `cut`, and demand bit-identity.
fn roundtrip_at(seed: u64, threads: usize, sync: CellSync, cut: f64) {
    let ctx = format!("seed {seed}, threads {threads}, sync {sync:?}, cut {cut}");
    let cold = rich(seed, threads, sync).run();

    let donor_sc = rich(seed, threads, sync);
    let mut donor = ScenarioEngine::new(&donor_sc);
    donor.run_to(cut);
    let blob = donor.snapshot();
    drop(donor);

    // Restore into a *fresh* scenario value: nothing may leak from the
    // donor engine besides the blob itself.
    let host_sc = rich(seed, threads, sync);
    let mut eng = ScenarioEngine::from_snapshot(&host_sc, &blob)
        .unwrap_or_else(|e| panic!("{ctx}: restore failed: {e}"));
    eng.run_to(f64::INFINITY);
    assert_results_identical(&cold, &eng.finish(), &ctx);
}

#[test]
fn snapshot_resume_is_bit_identical_across_threads() {
    for threads in [1usize, 2, 4, 8] {
        roundtrip_at(7, threads, CellSync::Frontier, 1.7);
    }
    // Barrier scheduler and a cut inside the warmup window.
    roundtrip_at(7, 4, CellSync::Barrier, 0.3);
}

#[test]
fn snapshot_cut_points_never_change_results() {
    // Property: any cut — early, mid-run, near the horizon, or past
    // it (a drained engine) — restores bit-identically.
    check(4, |g| {
        let seed = g.u64_below(500);
        let cut = [0.05, 0.9, 2.2, 3.9, 4.5][g.usize_range(0, 4)];
        roundtrip_at(seed, 1, CellSync::Frontier, cut);
        Ok(())
    });
}

#[test]
fn snapshot_segmented_advance_matches_single_run() {
    // Several run_to segments before and after the checkpoint.
    let cold = rich(3, 2, CellSync::Frontier).run();
    let sc = rich(3, 2, CellSync::Frontier);
    let mut eng = ScenarioEngine::new(&sc);
    eng.run_to(0.4);
    eng.run_to(1.1);
    eng.run_to(1.1); // idempotent at the same bound
    let blob = eng.snapshot();
    drop(eng);
    let sc2 = rich(3, 2, CellSync::Frontier);
    let mut eng = ScenarioEngine::from_snapshot(&sc2, &blob).unwrap();
    eng.run_to(2.6);
    eng.run_to(f64::INFINITY);
    assert_results_identical(&cold, &eng.finish(), "segmented");
}

#[test]
fn snapshot_restore_snapshot_is_byte_stable() {
    let sc = rich(11, 1, CellSync::Frontier);
    let mut eng = ScenarioEngine::new(&sc);
    eng.run_to(1.3);
    let blob = eng.snapshot();
    drop(eng);
    let eng = ScenarioEngine::from_snapshot(&sc, &blob).unwrap();
    assert_eq!(blob, eng.snapshot(), "restore must not perturb a single byte");
}

#[test]
fn snapshot_rejects_garbage_with_clear_errors() {
    let sc = rich(5, 1, CellSync::Frontier);
    let mut eng = ScenarioEngine::new(&sc);
    eng.run_to(1.0);
    let blob = eng.snapshot();
    drop(eng);

    // Wrong magic.
    let mut bad = blob.clone();
    bad[0] ^= 0xff;
    assert_eq!(ScenarioEngine::from_snapshot(&sc, &bad).err(), Some(SnapError::BadMagic));
    assert_eq!(
        ScenarioEngine::from_snapshot(&sc, b"not a snapshot").err(),
        Some(SnapError::BadMagic)
    );

    // Version skew (bytes 8..12, little-endian after the 8-byte magic).
    let mut bad = blob.clone();
    bad[MAGIC.len()..MAGIC.len() + 4].copy_from_slice(&(VERSION + 9).to_le_bytes());
    assert_eq!(
        ScenarioEngine::from_snapshot(&sc, &bad).err(),
        Some(SnapError::VersionMismatch { found: VERSION + 9, expected: VERSION })
    );

    // Structurally different scenario: one more node.
    let other = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .seed(5)
        .workload(WorkloadClass::chat())
        .node(gpu(), 1)
        .node(gpu(), 1)
        .build();
    assert!(matches!(
        ScenarioEngine::from_snapshot(&other, &blob).err(),
        Some(SnapError::FingerprintMismatch { .. })
    ));

    // Every truncation must be rejected (never panic, never succeed).
    for len in (0..blob.len()).step_by(7).chain(blob.len() - 3..blob.len()) {
        match ScenarioEngine::from_snapshot(&sc, &blob[..len]).err() {
            Some(
                SnapError::Truncated { .. } | SnapError::Corrupt { .. } | SnapError::BadMagic,
            ) => {}
            other => panic!("truncation to {len} bytes: {other:?}"),
        }
    }

    // Trailing junk is corruption, not padding.
    let mut bad = blob.clone();
    bad.push(0);
    assert!(matches!(
        ScenarioEngine::from_snapshot(&sc, &bad).err(),
        Some(SnapError::Corrupt { .. })
    ));

    // The pristine blob still restores after all of the above.
    assert!(ScenarioEngine::from_snapshot(&sc, &blob).is_ok());
}

/// Fixed-population scenario whose arrival rate steps to `x` at t = 2
/// after a shared constant prefix — the shape a warm-started sweep
/// forks across.
fn phased(x: f64, seed: u64) -> Scenario {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(6.0)
        .warmup(0.5)
        .seed(seed)
        .workload(WorkloadClass::translation().with_rate(0.8).with_rate_phase(2.0, x))
        .cells(2, CellSpec::new(6))
        .node(gpu(), 1)
        .node(gpu(), 1)
        .build()
}

#[test]
fn warm_sweep_is_bit_identical_to_cold_on_invariant_prefix() {
    let xs = [0.8, 1.6, 2.4];
    let seeds = [11u64, 1011];
    let cold = sweep_grid(&xs, &seeds, 2, |x, s| phased(x, s).run().report);
    let warm = sweep_grid_warm(&xs, &seeds, 2.0, 2, WarmStart::Exact, phased);
    assert_eq!(cold.len(), warm.len());
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.x.to_bits(), w.x.to_bits());
        assert_eq!(c.n_reps, w.n_reps);
        assert_eq!(
            c.report.to_json(),
            w.report.to_json(),
            "x = {}: warm point diverged from cold",
            c.x
        );
    }
}

#[test]
#[should_panic(expected = "WarmStart::Exact requires")]
fn warm_sweep_exact_rejects_varying_prefix() {
    // The rate already differs inside [0, 2): Exact must refuse.
    let make = |x: f64, seed: u64| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(4.0)
            .seed(seed)
            .workload(WorkloadClass::translation().with_rate(x))
            .node(gpu(), 1)
            .build()
    };
    sweep_grid_warm(&[0.5, 1.0], &[1], 2.0, 1, WarmStart::Exact, make);
}

#[test]
fn redispatch_reprices_on_destination_tier() {
    // Two *different* GPU tiers behind least-loaded routing. Node 0
    // (the fast tier) fails early and never repairs, so its queue is
    // re-dispatched to the slow tier. Deterministic roofline service
    // on fixed token counts means every tier has exactly one legal
    // service time — and the per-tier outcome counts must reconcile
    // with the cluster ledger's per-node `served` counters, which they
    // only do when a re-dispatched job is re-priced on the
    // *destination* roofline (DESIGN.md §11).
    let class = WorkloadClass::translation()
        .with_rate(2.0)
        .with_input(TokenDist::Fixed(256))
        .with_output(TokenDist::Fixed(128))
        .with_budget(5.0);
    let fast = gpu();
    let slow = GpuSpec::a100().scaled(8.0);
    let spec = class.job_spec(256, 128);
    let s_fast = CostModel::new(fast).total_latency(&spec);
    let s_slow = CostModel::new(slow).total_latency(&spec);
    assert_ne!(s_fast.to_bits(), s_slow.to_bits(), "tiers must price differently");

    let res = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(6.0)
        .warmup(0.0)
        .seed(13)
        .routing(RoutingPolicy::LeastLoaded)
        .service_kind(ServiceModelKind::Roofline)
        .workload(class)
        .cell(CellSpec::new(16))
        .node(fast, 1)
        .node_churn(NodeChurnSpec { mtbf: 0.5, mttr: 1e9, spinup: 0.0 })
        .node(slow, 1)
        .cluster(ClusterSpec { retry_budget: 1, ..Default::default() })
        .build()
        .run();

    let cl = &res.report.cluster;
    assert!(!cl.is_empty());
    let failures: u64 = cl.nodes.iter().map(|n| n.failures).sum();
    let redispatched: u64 = cl.nodes.iter().map(|n| n.redispatched).sum();
    assert!(failures >= 1, "the fast tier never failed — the test exercises nothing");
    assert!(redispatched >= 1, "no job crossed tiers — the test exercises nothing");

    let completed: Vec<_> =
        res.outcomes.iter().filter(|o| o.fate == JobFate::Completed).collect();
    assert!(!completed.is_empty());
    let n_fast =
        completed.iter().filter(|o| o.t_service.to_bits() == s_fast.to_bits()).count() as u64;
    let n_slow =
        completed.iter().filter(|o| o.t_service.to_bits() == s_slow.to_bits()).count() as u64;
    // Every completed job carries exactly one tier's roofline…
    assert_eq!(
        n_fast + n_slow,
        completed.len() as u64,
        "a completed job carries a service time priced on neither tier"
    );
    // …and the tier is the one that actually served it.
    assert_eq!(n_fast, cl.nodes[0].served, "fast-tier pricing vs fast-tier serves");
    assert_eq!(n_slow, cl.nodes[1].served, "slow-tier pricing vs slow-tier serves");
}

/// Single-cell, single-node scenario with an arbitrary workload class
/// — the rate-phase boundary rig.
fn one_class(class: WorkloadClass, seed: u64, horizon: f64) -> Scenario {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(horizon)
        .warmup(0.0)
        .seed(seed)
        .workload(class)
        .cell(CellSpec::new(8))
        .node(gpu(), 1)
        .build()
}

#[test]
fn rate_phase_at_horizon_never_takes_effect() {
    // Arrivals at t >= horizon are discarded, so a phase starting
    // exactly at the horizon must not change one bit.
    check(4, |g| {
        let seed = g.u64_below(500);
        let plain = one_class(WorkloadClass::translation(), seed, 3.0).run();
        let phased =
            one_class(WorkloadClass::translation().with_rate_phase(3.0, 50.0), seed, 3.0)
                .run();
        prop_assert!(plain.events == phased.events, "seed {seed}: event counts diverged");
        prop_assert!(
            plain.report.to_json() == phased.report.to_json(),
            "seed {seed}: a phase at the horizon changed the results"
        );
        Ok(())
    });
}

#[test]
fn single_phase_from_zero_equals_constant_rate() {
    // A one-phase schedule starting at t = 0 is the constant rate it
    // names: the draws must match bit for bit regardless of the
    // (never in force) base rate.
    check(4, |g| {
        let seed = g.u64_below(500);
        let constant = one_class(WorkloadClass::translation().with_rate(1.3), seed, 3.0).run();
        let scheduled = one_class(
            WorkloadClass::translation().with_rate(0.2).with_rate_phase(0.0, 1.3),
            seed,
            3.0,
        )
        .run();
        prop_assert!(
            constant.events == scheduled.events
                && constant.report.to_json() == scheduled.report.to_json(),
            "seed {seed}: single-phase schedule diverged from the constant rate"
        );
        Ok(())
    });
}

#[test]
fn zero_rate_phase_silences_then_resumes() {
    // rate 2.0 on [0, 1.5), silent on [1.5, 3.5), rate 2.0 after.
    let class = WorkloadClass::translation()
        .with_rate(2.0)
        .with_rate_phase(1.5, 0.0)
        .with_rate_phase(3.5, 2.0);
    let res = one_class(class.clone(), 19, 6.0).run();
    // Deterministic replay through the deferral path.
    let res2 = one_class(class, 19, 6.0).run();
    assert_eq!(res.events, res2.events);
    assert_eq!(res.report.to_json(), res2.report.to_json());

    let before = res.outcomes.iter().filter(|o| o.t_gen < 1.5).count();
    let during = res.outcomes.iter().filter(|o| o.t_gen >= 1.5 && o.t_gen < 3.5).count();
    let after = res.outcomes.iter().filter(|o| o.t_gen >= 3.5).count();
    assert!(before > 0, "no arrivals before the silence");
    assert!(after > 0, "the class never resumed after the zero phase");
    // At most one already-armed arrival per (UE, class) stream may
    // leak into the silent window (documented discretization).
    assert!(during <= 8, "{during} arrivals during a zero-rate phase (8 streams)");
}

#[test]
fn zero_rate_tail_goes_permanently_silent() {
    // A final zero phase with no positive phase after it: the stream
    // must stop without drawing (and the run must still terminate).
    let class = WorkloadClass::translation().with_rate(2.0).with_rate_phase(1.0, 0.0);
    let res = one_class(class, 23, 6.0).run();
    let late = res.outcomes.iter().filter(|o| o.t_gen >= 1.0).count();
    assert!(late <= 8, "{late} arrivals after a permanent silence (8 streams)");
    assert!(res.outcomes.iter().any(|o| o.t_gen < 1.0));
}

#[test]
fn toml_rate_phase_accepts_zero_and_rejects_negative() {
    let base = r#"
[[workload]]
name = "w"
rate_per_ue = 1.0

[[workload.rate_phase]]
class = "w"
t_start = 2.0
rate_per_ue = 0.0
"#;
    let doc = Document::parse(base).unwrap();
    let sc = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(3.0)
        .node(gpu(), 1)
        .apply_toml(&doc)
        .expect("zero-rate phase is legal")
        .try_build()
        .expect("zero-rate phase must build");
    assert_eq!(sc.classes()[0].rate_at(2.5), 0.0);

    let doc = Document::parse(&base.replace("rate_per_ue = 0.0", "rate_per_ue = -1.0"))
        .unwrap();
    let err = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .node(gpu(), 1)
        .apply_toml(&doc)
        .err()
        .expect("negative phase rate must be rejected");
    assert!(err.to_string().contains("rate_per_ue >= 0"), "{err}");
}
