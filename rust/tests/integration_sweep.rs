//! Parallel-sweep integration tests: the thread count must never
//! change a single bit of the merged reports, and the new CLI
//! subcommand must drive the grid end-to-end.

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{sweep_arrival_rates, sweep_arrival_rates_threaded};
use icc6g::sim::run_scheme;
use icc6g::sweep::{replication_seeds, run_parallel, sweep_grid};

fn small_base() -> SimConfig {
    let mut cfg = SimConfig::table1();
    cfg.horizon = 3.0;
    cfg.warmup = 0.5;
    cfg
}

#[test]
fn parallel_sweep_reports_bit_identical_to_serial() {
    let base = small_base();
    let scheme = SchemeConfig::icc();
    let rates = [10.0, 30.0, 50.0];
    let seeds = replication_seeds(base.seed, 3);

    let run = |rate: f64, seed: u64| {
        let mut cfg = base.clone();
        cfg.n_ues = (rate / cfg.job_traffic.rate_per_ue).round().max(1.0) as u32;
        run_scheme(&cfg, scheme.clone(), seed)
    };
    let serial = sweep_grid(&rates, &seeds, 1, run);
    let parallel = sweep_grid(&rates, &seeds, 4, run);
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.x.to_bits(), p.x.to_bits());
        assert_eq!(s.n_reps, p.n_reps);
        // exact counts AND bit-exact merged accumulators
        assert_eq!(s.report.n_jobs, p.report.n_jobs);
        assert_eq!(s.report.n_satisfied, p.report.n_satisfied);
        assert_eq!(s.report.n_dropped, p.report.n_dropped);
        assert_eq!(s.report.e2e.mean().to_bits(), p.report.e2e.mean().to_bits());
        assert_eq!(s.report.comm.mean().to_bits(), p.report.comm.mean().to_bits());
        assert_eq!(s.report.ttft.mean().to_bits(), p.report.ttft.mean().to_bits());
        // per-class slices survive the merge identically
        assert_eq!(s.report.per_class.len(), p.report.per_class.len());
        for (a, b) in s.report.per_class.iter().zip(&p.report.per_class) {
            assert_eq!(a.n_jobs, b.n_jobs);
            assert_eq!(a.ttft_samples(), b.ttft_samples());
        }
    }
}

#[test]
fn coordinator_threaded_sweep_matches_serial_curve() {
    let base = small_base();
    let scheme = SchemeConfig::mec();
    let rates = [20.0, 60.0];
    let serial = sweep_arrival_rates(&base, &scheme, &rates, 2);
    let threaded = sweep_arrival_rates_threaded(&base, &scheme, &rates, 2, 0);
    assert_eq!(serial.len(), threaded.len());
    for (s, p) in serial.iter().zip(&threaded) {
        assert_eq!(s.satisfaction.to_bits(), p.satisfaction.to_bits());
        assert_eq!(s.avg_comm_ms.to_bits(), p.avg_comm_ms.to_bits());
        assert_eq!(s.avg_comp_ms.to_bits(), p.avg_comp_ms.to_bits());
        assert_eq!(s.avg_tokens_per_sec.to_bits(), p.avg_tokens_per_sec.to_bits());
    }
}

#[test]
fn run_parallel_scales_to_many_more_items_than_threads() {
    let items: Vec<u64> = (0..500).collect();
    let out = run_parallel(&items, 3, |&x| x * x);
    assert_eq!(out.len(), 500);
    for (i, v) in out.iter().enumerate() {
        assert_eq!(*v, (i * i) as u64);
    }
}

#[test]
fn replication_is_deterministic_under_repeated_parallel_runs() {
    // Same grid twice in parallel → identical results (no hidden
    // shared state across workers).
    let base = small_base();
    let scheme = SchemeConfig::icc();
    let rates = [40.0];
    let seeds = replication_seeds(7, 4);
    let run = |rate: f64, seed: u64| {
        let mut cfg = base.clone();
        cfg.n_ues = (rate / cfg.job_traffic.rate_per_ue).round().max(1.0) as u32;
        run_scheme(&cfg, scheme.clone(), seed)
    };
    let a = sweep_grid(&rates, &seeds, 0, run);
    let b = sweep_grid(&rates, &seeds, 0, run);
    assert_eq!(a[0].report.n_jobs, b[0].report.n_jobs);
    assert_eq!(a[0].report.n_satisfied, b[0].report.n_satisfied);
    assert_eq!(a[0].report.e2e.mean().to_bits(), b[0].report.e2e.mean().to_bits());
}
