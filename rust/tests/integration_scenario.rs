//! Integration tests for the Scenario API: mixed-workload determinism,
//! per-class accounting, multi-node routing, TOML round-trips, and
//! equivalence of the legacy `Sls` wrapper with a hand-built
//! single-class scenario.

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::llm::GpuSpec;
use icc6g::metrics::SimReport;
use icc6g::scenario::{
    workloads_from_toml, workloads_to_toml, RoutingPolicy, ScenarioBuilder,
    ServiceModelKind, TokenDist, WorkloadClass,
};
use icc6g::sim::Sls;
use icc6g::util::tomlmini::Document;

fn mixed_builder(seed: u64) -> ScenarioBuilder {
    ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .n_ues(30)
        .horizon(6.0)
        .warmup(1.0)
        .seed(seed)
        .workload(WorkloadClass::translation())
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::summarization())
        .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
        .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
        .service_kind(ServiceModelKind::TokenSampled)
        .routing(RoutingPolicy::LeastLoaded)
}

#[test]
fn mixed_workloads_deterministic_given_seed() {
    let a = mixed_builder(11).build().run();
    let b = mixed_builder(11).build().run();
    assert_eq!(a.report.n_jobs, b.report.n_jobs);
    assert_eq!(a.report.n_satisfied, b.report.n_satisfied);
    assert_eq!(a.report.n_dropped, b.report.n_dropped);
    assert_eq!(a.events, b.events);
    assert!((a.report.e2e.mean() - b.report.e2e.mean()).abs() < 1e-12);
    for (ca, cb) in a.report.per_class.iter().zip(&b.report.per_class) {
        assert_eq!(ca.n_jobs, cb.n_jobs, "class '{}'", ca.name);
        assert_eq!(ca.n_satisfied, cb.n_satisfied, "class '{}'", ca.name);
    }
    // a different seed must change the trajectory
    let c = mixed_builder(12).build().run();
    assert!(
        (a.report.e2e.mean() - c.report.e2e.mean()).abs() > 1e-12,
        "different seeds must diverge"
    );
}

#[test]
fn per_class_reports_sum_to_overall() {
    let res = mixed_builder(5).build().run();
    assert_eq!(res.report.per_class.len(), 3);
    let (mut jobs, mut sat, mut dropped, mut comm_n) = (0u64, 0u64, 0u64, 0u64);
    for c in &res.report.per_class {
        assert!(c.n_jobs > 0, "class '{}' generated no jobs", c.name);
        jobs += c.n_jobs;
        sat += c.n_satisfied;
        dropped += c.n_dropped;
        comm_n += c.comm.count();
    }
    assert_eq!(jobs, res.report.n_jobs);
    assert_eq!(sat, res.report.n_satisfied);
    assert_eq!(dropped, res.report.n_dropped);
    assert_eq!(comm_n, res.report.comm.count());
    assert!(res.events > res.report.n_jobs);
}

#[test]
fn routing_policies_all_serve_the_mix() {
    for policy in [
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
        RoutingPolicy::ClassAffinity,
    ] {
        let res = mixed_builder(3).routing(policy).build().run();
        assert!(
            res.report.n_jobs > 50,
            "{}: n = {}",
            policy.name(),
            res.report.n_jobs
        );
        let completed = res.report.comp.count();
        assert!(completed > 0, "{}: nothing served", policy.name());
    }
}

#[test]
fn single_class_scenario_matches_legacy_sls() {
    // The wrapper path and a hand-built single-class scenario must
    // produce the same trajectory (same streams, same event order).
    let mut cfg = SimConfig::table1().with_scheme(SchemeConfig::icc());
    cfg.n_ues = 20;
    cfg.horizon = 5.0;
    cfg.warmup = 1.0;
    cfg.seed = 9;
    let legacy = Sls::new(cfg.clone()).run();
    let scenario = ScenarioBuilder::from_sim_config(&cfg).build().run();
    assert_eq!(legacy.report.n_jobs, scenario.report.n_jobs);
    assert_eq!(legacy.report.n_satisfied, scenario.report.n_satisfied);
    assert_eq!(legacy.events, scenario.events);
    assert!((legacy.report.e2e.mean() - scenario.report.e2e.mean()).abs() < 1e-12);
}

#[test]
fn workload_tables_round_trip_through_toml() {
    let classes = vec![
        WorkloadClass::chat(),
        WorkloadClass::summarization().with_input(TokenDist::Uniform { lo: 128, hi: 384 }),
        WorkloadClass::translation().with_rate(2.0),
    ];
    let text = workloads_to_toml(&classes);
    let doc = Document::parse(&text).expect("emitted TOML must parse");
    let back = workloads_from_toml(&doc).unwrap();
    assert_eq!(classes, back);

    // unknown keys inside a [[workload]] table are rejected
    let doc = Document::parse(
        "[[workload]]\nname = \"chat\"\nrate_per_ue = 0.5\nturbo = true",
    )
    .unwrap();
    let err = workloads_from_toml(&doc).unwrap_err();
    assert!(err.to_string().contains("turbo"), "{err}");
}

#[test]
fn scenario_toml_end_to_end() {
    let doc = Document::parse(
        "[scenario]\nn_ues = 16\nhorizon = 4.0\nwarmup = 1.0\nseed = 2\n\
         [scheme]\npreset = \"icc\"\n\
         [service]\nmodel = \"token_sampled\"\n\
         [routing]\npolicy = \"affinity\"\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n\
         [[workload]]\nname = \"translation\"\n\
         [[workload]]\nname = \"chat\"\nrate_per_ue = 0.3\ninput = \"geometric:48\"\noutput = \"geometric:96\"\nb_total = 0.5\n",
    )
    .unwrap();
    let scenario = ScenarioBuilder::new().apply_toml(&doc).unwrap().build();
    assert_eq!(scenario.classes().len(), 2);
    assert_eq!(scenario.nodes().len(), 2);
    let res = scenario.run();
    assert_eq!(res.report.per_class.len(), 2);
    assert!(res.report.n_jobs > 0);
    let total: u64 = res.report.per_class.iter().map(|c| c.n_jobs).sum();
    assert_eq!(total, res.report.n_jobs);
}

#[test]
fn ttft_tpot_slices_consistent_with_e2e() {
    let res = mixed_builder(13).build().run();
    for o in res.outcomes.iter() {
        match o.fate {
            icc6g::metrics::JobFate::Completed => {
                assert!(o.ttft > 0.0, "job {}: ttft must be positive", o.job_id);
                assert!(
                    o.ttft <= o.e2e() + 1e-12,
                    "job {}: ttft {} beyond e2e {}",
                    o.job_id,
                    o.ttft,
                    o.e2e()
                );
                assert!(o.tpot >= 0.0);
            }
            _ => {
                assert_eq!(o.ttft, 0.0);
                assert_eq!(o.tpot, 0.0);
            }
        }
    }
    for c in &res.report.per_class {
        // one TTFT/TPOT sample per completed job, nothing more
        assert_eq!(c.ttft.count(), c.comp.count(), "class '{}'", c.name);
        assert_eq!(c.ttft_samples().len() as u64, c.ttft.count());
        assert_eq!(c.tpot_samples().len() as u64, c.tpot.count());
        if c.comp.count() > 0 {
            assert!(c.ttft.mean() <= c.e2e.mean() + 1e-12, "class '{}'", c.name);
            // percentiles are monotone in q
            let (p50, p95, p99) = (
                c.ttft_percentile(50.0),
                c.ttft_percentile(95.0),
                c.ttft_percentile(99.0),
            );
            assert!(p50 <= p95 && p95 <= p99, "class '{}': {p50} {p95} {p99}", c.name);
            assert!(p99 <= c.e2e.max() + 1e-12);
        }
    }
    // overall TTFT totals are the merge of the slices
    let slice_count: u64 = res.report.per_class.iter().map(|c| c.ttft.count()).sum();
    assert_eq!(res.report.ttft.count(), slice_count);
}

#[test]
fn ttft_slices_survive_replication_merge() {
    let mut a = mixed_builder(31).build().run().report;
    let b = mixed_builder(32).build().run().report;
    // expected: concatenation of the two replications' samples
    let expect: Vec<Vec<f64>> = a
        .per_class
        .iter()
        .zip(&b.per_class)
        .map(|(ca, cb)| {
            let mut v = ca.ttft_samples().to_vec();
            v.extend_from_slice(cb.ttft_samples());
            v
        })
        .collect();
    a.merge(&b);
    assert_eq!(a.per_class.len(), expect.len());
    for (c, want) in a.per_class.iter().zip(&expect) {
        assert_eq!(c.ttft_samples().len(), want.len(), "class '{}'", c.name);
        assert_eq!(c.ttft.count() as usize, want.len());
        for q in [50.0, 95.0, 99.0] {
            let merged = c.ttft_percentile(q);
            let exact = icc6g::util::stats::percentile(want, q);
            assert!(
                (merged - exact).abs() < 1e-15,
                "class '{}' p{q}: {merged} vs {exact}",
                c.name
            );
        }
    }
}

#[test]
fn report_satisfaction_consistent_with_per_class_rates() {
    let res = mixed_builder(21).build().run();
    let SimReport { n_jobs, n_satisfied, .. } = res.report.clone();
    let weighted: f64 = res
        .report
        .per_class
        .iter()
        .filter(|c| c.n_jobs > 0)
        .map(|c| c.satisfaction_rate() * c.n_jobs as f64)
        .sum();
    assert!((weighted - n_satisfied as f64).abs() < 1e-9);
    assert!(((n_satisfied as f64 / n_jobs as f64) - res.report.satisfaction_rate()).abs() < 1e-12);
}
