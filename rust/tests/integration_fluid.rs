//! Hybrid-fidelity (fluid far-ring tier) integration tests — DESIGN.md §15.
//!
//! 1. **Analytic cross-check** — on a symmetric hex grid the fluid
//!    tier's per-cell offered job rate is exactly `n_ues × Σ class
//!    rates`, its activities stay in `[0, 1]`, the Eq 3–6 closed forms
//!    are proper probabilities, and the interference a focus cell
//!    observes from fluid neighbors lands within an order of magnitude
//!    (linear) of the all-per-UE DES steady state.
//! 2. **Snapshot round-trip** — with the fluid tier live, a
//!    serialize → restore → serialize cycle is byte-stable and a run
//!    resumed from a mid-horizon snapshot finishes bit-identical to an
//!    uninterrupted one.
//! 3. **Bounded-lag determinism** — with fluid off (or the focus set
//!    covering every cell, which must build the identical engine) the
//!    bounded-lag frontier merge is bit-identical across worker-thread
//!    counts {1, 2, 4, 8} and both parallel cell schedulers; a hybrid
//!    run is likewise thread-invariant.

use icc6g::config::SchemeConfig;
use icc6g::scenario::{
    CellSpec, CellSync, FluidSpec, MobilitySpec, RoutingPolicy, Scenario,
    ScenarioBuilder, ScenarioEngine, ScenarioResult, ServiceModelKind,
    TopologySpec, WorkloadClass,
};

fn gpu() -> icc6g::llm::GpuSpec {
    icc6g::llm::GpuSpec::gh200_nvl2().scaled(2.0)
}

/// 19-site hex grid, focus on the center cell only: cell 0 keeps the
/// per-UE pipeline (plus ring 1 when `rings` = 1), the far ring goes
/// fluid. With `fluid` = `None` every cell is per-UE.
fn hex19(
    ues_per_cell: u32,
    fluid: Option<FluidSpec>,
    threads: usize,
    sync: CellSync,
    seed: u64,
) -> Scenario {
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(1.0)
        .warmup(0.2)
        .seed(seed)
        .threads(threads)
        .cell_sync(sync)
        .routing(RoutingPolicy::LeastLoaded)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat())
        .workload(WorkloadClass::translation())
        .cells(19, CellSpec::new(ues_per_cell))
        .topology(TopologySpec::hex(300.0))
        .node(gpu(), 1)
        .node(gpu(), 1);
    if let Some(f) = fluid {
        b = b.fluid(f);
    }
    b.build()
}

fn focus_center(rings: u32) -> FluidSpec {
    FluidSpec { focus: vec![0], rings, ..FluidSpec::default() }
}

#[test]
fn fluid_report_matches_closed_forms_on_symmetric_grid() {
    let res = hex19(6, Some(focus_center(0)), 1, CellSync::Frontier, 7).run();
    let fl = res.fluid.as_ref().expect("fluid tier configured but not reported");

    // Ring 0 of the 19-site spiral is just cell 0: 18 fluid cells.
    assert_eq!(fl.cells.len(), 18);
    let sc = hex19(6, Some(focus_center(0)), 1, CellSync::Frontier, 7);
    let rate_sum: f64 = sc.classes().iter().map(|c| c.rate_at(1.0)).sum();
    for fc in &fl.cells {
        assert!(fc.cell >= 1 && fc.cell <= 18, "cell 0 must stay per-UE");
        // λ per cell is exactly population × Σ rates (no sampling).
        let expect = 6.0 * rate_sum;
        assert!(
            (fc.lambda_jobs - expect).abs() <= 1e-12 * expect,
            "cell {}: λ {} vs {}",
            fc.cell,
            fc.lambda_jobs,
            expect
        );
        assert!((0.0..=1.0).contains(&fc.activity), "activity {}", fc.activity);
        assert!(
            (0.0..=1.0).contains(&fc.mean_activity),
            "mean activity {}",
            fc.mean_activity
        );
        // The symmetric grid gives every fluid cell the same capacity
        // and population, hence the same activity trajectory.
        assert_eq!(
            fc.activity.to_bits(),
            fl.cells[0].activity.to_bits(),
            "asymmetric activity on a symmetric grid"
        );
    }
    assert!(fl.node_rho >= 0.0 && fl.node_rho.is_finite());
    assert_eq!(fl.classes.len(), 2);
    for cr in &fl.classes {
        assert!(
            (0.0..=1.0).contains(&cr.satisfaction),
            "{}: satisfaction {}",
            cr.name,
            cr.satisfaction
        );
        assert!(cr.lambda_per_cell > 0.0);
        if let Some(w) = cr.mean_sojourn {
            assert!(w > 0.0 && w.is_finite(), "{}: sojourn {w}", cr.name);
        }
    }
    // The focus cell still simulates jobs per-UE.
    assert!(res.report.n_jobs > 0);
    assert_eq!(res.report.per_cell.iter().map(|c| c.n_jobs).sum::<u64>(), res.report.n_jobs);
    for c in &res.report.per_cell[1..] {
        assert_eq!(c.n_jobs, 0, "a fluid cell generated per-UE jobs");
    }
}

#[test]
fn fluid_interference_tracks_per_ue_des_steady_state() {
    // Same symmetric grid, every neighbor of cell 0 replaced by its
    // fluid counterpart vs the all-per-UE reference. The IoT cell 0
    // observes is the sum of the neighbors' published rows, so the
    // mean-field approximation must land within an order of magnitude
    // (linear power) of the DES steady state: |Δ mean IoT| ≤ 10 dB.
    let dense = hex19(6, None, 1, CellSync::Frontier, 11).run();
    let hybrid = hex19(6, Some(focus_center(0)), 1, CellSync::Frontier, 11).run();
    let d = dense.report.radio[0].iot_db.mean();
    let h = hybrid.report.radio[0].iot_db.mean();
    assert!(d.is_finite() && h.is_finite(), "IoT means: dense {d}, hybrid {h}");
    assert!(d > 0.0, "per-UE neighbors raised no interference at the focus cell");
    assert!(h > 0.0, "fluid neighbors raised no interference at the focus cell");
    assert!(
        (d - h).abs() <= 10.0,
        "fluid IoT {h:.2} dB vs per-UE {d:.2} dB — more than 10 dB apart"
    );
}

fn assert_bit_identical(a: &ScenarioResult, b: &ScenarioResult, tag: &str) {
    assert_eq!(a.events, b.events, "{tag}: event counts diverged");
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{tag}: job counts diverged");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert!(
            x.job_id == y.job_id
                && x.cell_id == y.cell_id
                && x.class_id == y.class_id
                && x.t_gen.to_bits() == y.t_gen.to_bits()
                && x.t_comm.to_bits() == y.t_comm.to_bits()
                && x.t_queue.to_bits() == y.t_queue.to_bits()
                && x.t_service.to_bits() == y.t_service.to_bits()
                && x.ttft.to_bits() == y.ttft.to_bits()
                && x.fate == y.fate,
            "{tag}: job diverged\n  a: {x:?}\n  b: {y:?}"
        );
    }
    assert_eq!(a.report.to_json(), b.report.to_json(), "{tag}: reports diverged");
    match (&a.fluid, &b.fluid) {
        (None, None) => {}
        (Some(fa), Some(fb)) => {
            assert_eq!(fa.cells.len(), fb.cells.len(), "{tag}");
            for (x, y) in fa.cells.iter().zip(&fb.cells) {
                assert_eq!(x.cell, y.cell, "{tag}");
                assert_eq!(x.activity.to_bits(), y.activity.to_bits(), "{tag}");
                assert_eq!(
                    x.mean_activity.to_bits(),
                    y.mean_activity.to_bits(),
                    "{tag}"
                );
            }
            assert_eq!(fa.node_rho.to_bits(), fb.node_rho.to_bits(), "{tag}");
        }
        _ => panic!("{tag}: fluid section present on one side only"),
    }
}

#[test]
fn fluid_snapshot_roundtrip_is_byte_stable_and_bit_identical() {
    let mk = || hex19(5, Some(focus_center(1)), 2, CellSync::Frontier, 13);
    let cold = mk().run();
    assert!(cold.fluid.is_some());

    let donor_sc = mk();
    let mut donor = ScenarioEngine::new(&donor_sc);
    donor.run_to(0.6);
    let blob = donor.snapshot();
    drop(donor);

    // serialize → restore → serialize must not perturb a single byte.
    let host_sc = mk();
    let eng = ScenarioEngine::from_snapshot(&host_sc, &blob).expect("restore failed");
    assert_eq!(blob, eng.snapshot(), "fluid snapshot not byte-stable");
    drop(eng);

    // ... and the resumed run finishes bit-identical to the cold one.
    let host_sc = mk();
    let mut eng = ScenarioEngine::from_snapshot(&host_sc, &blob).unwrap();
    eng.run_to(f64::INFINITY);
    assert_bit_identical(&cold, &eng.finish(), "fluid resume");

    // A scenario without the fluid tier must refuse the blob.
    let plain = hex19(5, None, 2, CellSync::Frontier, 13);
    assert!(
        ScenarioEngine::from_snapshot(&plain, &blob).is_err(),
        "a fluid snapshot restored into a fluid-less scenario"
    );
}

#[test]
fn fluid_off_and_focus_all_are_bit_identical_across_threads_and_schedulers() {
    // The fidelity contract's off switch: no [fluid] section, and a
    // focus set whose neighborhood covers the whole grid, both run the
    // plain per-UE engine — bit-identical to serial at every worker
    // count and under both parallel schedulers. Mobility keeps the
    // RadioTick writer live so the bounded-lag merge is exercised.
    let mk = |fluid: Option<FluidSpec>, threads: usize, sync: CellSync| {
        let mut b = ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(1.5)
            .warmup(0.3)
            .seed(17)
            .threads(threads)
            .cell_sync(sync)
            .service_kind(ServiceModelKind::TokenSampled)
            .workload(WorkloadClass::chat())
            .cells(7, CellSpec::new(4))
            .topology(TopologySpec::hex(300.0))
            .mobility(MobilitySpec::fixed(30.0))
            .node(gpu(), 1)
            .node(gpu(), 1);
        if let Some(f) = fluid {
            b = b.fluid(f);
        }
        b.build().run()
    };
    let serial = mk(None, 1, CellSync::Frontier);
    assert!(serial.report.n_jobs > 0);
    // Focus-all classifies zero cells fluid: same engine, same bits,
    // and no fluid section on the result.
    let all = mk(Some(focus_center(64)), 1, CellSync::Frontier);
    assert!(all.fluid.is_none(), "focus-all must disable the fluid tier");
    assert_bit_identical(&serial, &all, "focus-all serial");
    // CI's pdes-matrix job pins a single worker count per leg via
    // ICC6G_PDES_THREADS; a plain `cargo test` sweeps all of them.
    let counts: Vec<usize> = match std::env::var("ICC6G_PDES_THREADS") {
        Ok(v) => vec![v.parse().expect("ICC6G_PDES_THREADS must be a worker count")],
        Err(_) => vec![2, 4, 8],
    };
    for threads in counts {
        for sync in [CellSync::Frontier, CellSync::Barrier] {
            let tag = format!("{sync:?} x{threads}");
            assert_bit_identical(&serial, &mk(None, threads, sync), &format!("off {tag}"));
            assert_bit_identical(
                &serial,
                &mk(Some(focus_center(64)), threads, sync),
                &format!("focus-all {tag}"),
            );
        }
    }
}

#[test]
fn hybrid_run_is_thread_invariant() {
    // Fluid tier live (FluidTick writer in the calendar): the
    // bounded-lag frontier merge must still be bit-identical to the
    // serial engine at every worker count.
    let serial = hex19(5, Some(focus_center(1)), 1, CellSync::Frontier, 19).run();
    assert!(serial.fluid.is_some());
    for threads in [2usize, 4, 8] {
        let par = hex19(5, Some(focus_center(1)), threads, CellSync::Frontier, 19).run();
        assert_bit_identical(&serial, &par, &format!("hybrid x{threads}"));
    }
    let barrier = hex19(5, Some(focus_center(1)), 4, CellSync::Barrier, 19).run();
    assert_bit_identical(&serial, &barrier, "hybrid barrier x4");
}
