//! CLI smoke tests: run the `icc6g` binary end-to-end and check its
//! output contains the paper's reproduction rows.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_icc6g"))
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["fig4", "fig6", "fig7", "simulate", "serve", "generate", "bench-diff"] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn fig4_reproduces_98_percent_gain() {
    let out = bin().args(["fig4", "--points", "5"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("service capacity"), "{text}");
    // The joint-RAN row must report a gain in the +85..+115% band.
    // Skip the curve-table header (which also names the scheme): the
    // capacity row is the one that ends in a percentage.
    let gain_line = text
        .lines()
        .find(|l| l.contains("ICC joint") && l.trim_end().ends_with('%'))
        .expect("joint capacity row missing");
    let pct: f64 = gain_line
        .split('+')
        .next_back()
        .unwrap()
        .trim_end_matches('%')
        .trim()
        .parse()
        .expect("gain percentage");
    assert!((85.0..=115.0).contains(&pct), "gain {pct}% (paper: 98%)");
}

#[test]
fn simulate_prints_report() {
    let out = bin()
        .args(["simulate", "--scheme", "icc", "--ues", "20", "--horizon", "4"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["satisfaction", "avg comm", "avg comp", "avg e2e"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
}

#[test]
fn simulate_rejects_bad_scheme() {
    let out = bin().args(["simulate", "--scheme", "zzz"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn fig_commands_have_help() {
    for cmd in ["fig4", "fig6", "fig7", "simulate", "scenario"] {
        let out = bin().args([cmd, "--help"]).output().unwrap();
        assert!(out.status.success(), "{cmd} --help failed");
        assert!(String::from_utf8_lossy(&out.stdout).contains("Options"));
    }
}

#[test]
fn scenario_prints_per_class_breakdown() {
    let out = bin()
        .args(["scenario", "--ues", "10", "--horizon", "3", "--nodes", "2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["per-class breakdown", "translation", "chat", "summarization", "events"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
}

#[test]
fn scenario_rejects_bad_routing() {
    let out = bin().args(["scenario", "--routing", "zzz"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn scenario_reports_ttft_tpot_and_writes_json() {
    let dir = std::env::temp_dir().join(format!("icc6g_json_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("report.json");
    let out = bin()
        .args([
            "scenario",
            "--ues",
            "8",
            "--horizon",
            "2",
            "--json",
            path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["ttft_p50", "ttft_p95", "ttft_p99", "tpot_p95"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
    let js = std::fs::read_to_string(&path).unwrap();
    for field in ["\"per_class\"", "\"ttft_ms\"", "\"tpot_ms\"", "\"p99\"", "\"n_jobs\""] {
        assert!(js.contains(field), "missing {field} in JSON:\n{js}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_runs_small_grid_and_reports_capacity() {
    // Run in a temp dir: sweep writes CSVs into its CWD.
    let dir = std::env::temp_dir().join(format!("icc6g_sweep_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = bin()
        .current_dir(&dir)
        .args([
            "sweep", "--scheme", "icc", "--rates", "10:30:2", "--seeds", "2",
            "--threads", "2", "--horizon", "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["service capacity", "satisfaction", "thread", "replications"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
    assert!(dir.join("bench_out").join("sweep_curves.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_multi_cell_prints_per_cell_breakdown_threaded() {
    // ≥4 cells with cell-affinity routing on worker threads — the
    // acceptance topology — must report per-cell columns.
    let dir = std::env::temp_dir().join(format!("icc6g_cells_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = bin()
        .current_dir(&dir)
        .args([
            "scenario", "--ues", "16", "--cells", "4", "--threads", "2", "--nodes",
            "4", "--routing", "cell_affinity", "--horizon", "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["cells        : 4", "cell_affinity", "per-cell breakdown", "cell0", "cell3"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
    assert!(dir.join("bench_out").join("scenario_cells.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_coupled_radio_flags_print_topology_and_radio_table() {
    let dir = std::env::temp_dir().join(format!("icc6g_radio_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = bin()
        .current_dir(&dir)
        .args([
            "scenario", "--ues", "18", "--cells", "3", "--nodes", "3", "--routing",
            "cell_affinity", "--horizon", "2", "--isd", "400", "--speed", "20",
            "--handover",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for field in [
        "topology     : hex grid, ISD 400 m",
        "A3 handover",
        "per-cell radio",
        "avg_iot_db",
    ] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
    assert!(dir.join("bench_out").join("scenario_radio.csv").exists());
    // the coupled surfaces require a topology
    let out = bin().args(["scenario", "--handover"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--isd"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scenario_cell_toml_config_drives_a_sharded_run() {
    let dir = std::env::temp_dir().join(format!("icc6g_celltoml_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("cells.toml");
    std::fs::write(
        &cfg,
        "[scenario]\nhorizon = 3.0\nthreads = 2\n\
         [routing]\npolicy = \"cell_affinity\"\nspill_queue = 4\n\
         [[cell]]\nues = 4\ncount = 4\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n\
         [[node]]\ngpu = \"gh200\"\nscale = 2\n",
    )
    .unwrap();
    let out = bin()
        .current_dir(&dir)
        .args(["scenario", "--config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for field in ["cells        : 4", "16 UEs total", "cell_affinity", "cell0"] {
        assert!(text.contains(field), "missing '{field}' in:\n{text}");
    }
    // unknown [[cell]] keys must be rejected loudly
    std::fs::write(&cfg, "[[cell]]\nues = 4\nwarp = 9\n").unwrap();
    let out = bin()
        .current_dir(&dir)
        .args(["scenario", "--config", cfg.to_str().unwrap()])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("warp"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bench_diff_gates_on_regression_and_passes_in_tolerance() {
    let dir = std::env::temp_dir().join(format!("icc6g_gate_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // fake bench outputs in the shapes the real benches emit
    std::fs::write(
        dir.join("BENCH_scale.json"),
        "[\n  {\"name\": \"sls_scale\", \"n_ues\": 1000, \"mode\": \"active_set\", \
         \"events\": 100, \"jobs\": 10, \"wall_s\": 0.1, \"events_per_sec\": 1000000.0},\n  \
         {\"name\": \"speedup_vs_dense\", \"n_ues\": 1000, \"speedup\": 4.0}\n]\n",
    )
    .unwrap();
    std::fs::write(
        dir.join("baseline.json"),
        "{\"tolerance\": 0.25, \"entries\": [\n  \
         {\"key\": \"scale/sls_scale/1000/active_set/events_per_sec\", \"value\": 900000.0, \"higher_is_better\": true}\n]}\n",
    )
    .unwrap();
    // current (1.0M ev/s) vs baseline (0.9M): within tolerance → exit 0
    let args = [
        "bench-diff", "--baseline", "baseline.json", "--scale", "BENCH_scale.json",
        "--hotpath", "missing.json",
    ];
    let out = bin().current_dir(&dir).args(args).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("| metric |"), "no delta table:\n{text}");
    assert!(text.contains("ok"), "{text}");

    // injected 2x slowdown → the gate must fail
    std::fs::write(
        dir.join("BENCH_scale.json"),
        "[\n  {\"name\": \"sls_scale\", \"n_ues\": 1000, \"mode\": \"active_set\", \
         \"events\": 100, \"jobs\": 10, \"wall_s\": 0.2, \"events_per_sec\": 450000.0}\n]\n",
    )
    .unwrap();
    let out = bin().current_dir(&dir).args(args).output().unwrap();
    assert_eq!(out.status.code(), Some(1), "2x slowdown must fail the gate");
    assert!(String::from_utf8_lossy(&out.stdout).contains("REGRESSED"));

    // --update refreshes the baseline and the refreshed gate passes
    let out = bin()
        .current_dir(&dir)
        .args([
            "bench-diff", "--baseline", "baseline.json", "--scale",
            "BENCH_scale.json", "--hotpath", "missing.json", "--update",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let out = bin().current_dir(&dir).args(args).output().unwrap();
    assert!(out.status.success(), "refreshed baseline must pass");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_rejects_bad_grid_and_scheme() {
    let out = bin().args(["sweep", "--rates", "nonsense"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let out = bin().args(["sweep", "--scheme", "zzz"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let out = bin().args(["sweep", "--help"]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("Options"));
}
