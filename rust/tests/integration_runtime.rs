//! Cross-language integration: the Rust PJRT engine must reproduce the
//! Python/JAX golden trace bit-exactly, proving L1 (Pallas kernels),
//! L2 (JAX model) and the Rust runtime agree.
//!
//! These tests need `make artifacts`; they self-skip when the
//! artifacts directory is absent (e.g. pure-Rust CI shards).

use std::path::PathBuf;

use icc6g::runtime::{tokenizer, Engine};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("prefill.hlo.txt").exists().then_some(dir)
}

fn load_engine() -> Option<Engine> {
    artifacts_dir().map(|d| Engine::load(&d).expect("engine must load"))
}

/// Parse artifacts/golden_trace.txt → (prompt, expected_output).
fn golden() -> Option<(Vec<i32>, Vec<i32>)> {
    let dir = artifacts_dir()?;
    let text = std::fs::read_to_string(dir.join("golden_trace.txt")).ok()?;
    let mut prompt = None;
    let mut output = None;
    for line in text.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("prompt") => prompt = Some(it.map(|t| t.parse().unwrap()).collect()),
            Some("output") => output = Some(it.map(|t| t.parse().unwrap()).collect()),
            _ => {}
        }
    }
    Some((prompt?, output?))
}

#[test]
fn golden_trace_bit_exact() {
    let (Some(engine), Some((prompt, expected))) = (load_engine(), golden()) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (out, stats) = engine.generate(&prompt, expected.len()).unwrap();
    assert_eq!(out, expected, "rust generation diverged from the python golden trace");
    assert_eq!(stats.tokens_out, expected.len());
    assert!(stats.prefill_s > 0.0 && stats.decode_s > 0.0);
}

#[test]
fn generation_is_deterministic() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let prompt = tokenizer::encode("determinism check");
    let (a, _) = engine.generate(&prompt, 8).unwrap();
    let (b, _) = engine.generate(&prompt, 8).unwrap();
    assert_eq!(a, b);
}

#[test]
fn decode_steps_agree_with_prefill_logits() {
    // Prefilling [p0..pn] must give the same next-token choice as
    // prefilling [p0..pk] and decoding the rest step by step.
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let full = tokenizer::encode("abcdefgh");
    let k = 4;
    let (logits_full, _) = engine.prefill(&full).unwrap();
    let v = engine.meta.vocab;

    let (logits_pre, mut kv) = engine.prefill(&full[..k]).unwrap();
    // feed tokens k..len one at a time
    let mut last_logits: Vec<f32> = logits_pre[(k - 1) * v..k * v].to_vec();
    for (i, &tok) in full[k..].iter().enumerate() {
        // prefill's row (k-1+i) must match the decode path's logits
        let row = (k + i - 1) * v..(k + i) * v;
        let expect = &logits_full[row];
        for (a, b) in last_logits.iter().zip(expect) {
            assert!((a - b).abs() < 5e-3, "logits diverged: {a} vs {b}");
        }
        let (lg, kv2) = engine.decode_step(tok, kv).unwrap();
        kv = kv2;
        last_logits = lg;
    }
}

#[test]
fn prompt_length_limits_enforced() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(engine.prefill(&[]).is_err());
    let too_long = vec![1i32; engine.meta.max_seq + 1];
    assert!(engine.prefill(&too_long).is_err());
    // exactly max_seq is fine
    let max = vec![1i32; engine.meta.max_seq];
    assert!(engine.prefill(&max).is_ok());
}

#[test]
fn generate_stops_at_cache_capacity() {
    let Some(engine) = load_engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let prompt = vec![1i32; engine.meta.max_seq - 2];
    let (out, _) = engine.generate(&prompt, 50).unwrap();
    // only max_seq - prompt.len() = 2 decode positions exist; the
    // first token comes from prefill, then the cache fills.
    assert!(out.len() <= 3, "out len = {}", out.len());
    assert!(!out.is_empty());
}

#[test]
fn weights_match_meta_param_count() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let w = icc6g::runtime::Weights::load(&dir.join("weights.bin")).unwrap();
    let meta = icc6g::runtime::ModelMeta::load(&dir.join("model_meta.txt")).unwrap();
    assert_eq!(w.total_params(), meta.n_params);
    // canonical tensor set
    for name in ["embed", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                 "norm_attn", "norm_mlp", "norm_f", "unembed"] {
        assert!(w.by_name(name).is_some(), "missing tensor {name}");
    }
}
