//! System-level integration tests: the SLS must reproduce the paper's
//! qualitative results at reduced scale, and the analytic + simulated
//! layers must agree directionally.

use icc6g::config::{SchemeConfig, SimConfig};
use icc6g::coordinator::{
    capacity_from_curve, min_capacity_from_curve, sweep_arrival_rates, sweep_gpu_capacity,
};
use icc6g::llm::GpuSpec;
use icc6g::queueing::analytic::{scheme_satisfaction, SystemParams};
use icc6g::queueing::{service_capacity, Scheme};
use icc6g::sim::run_scheme;

fn base() -> SimConfig {
    let mut c = SimConfig::table1();
    c.horizon = 10.0;
    c.warmup = 1.5;
    c
}

#[test]
fn fig6_scheme_ordering_reproduced() {
    // Capacity(ICC) > Capacity(disjoint-RAN) > Capacity(MEC), with the
    // ICC gain over MEC in the paper's ballpark (+60%; accept 25–110%).
    let rates: Vec<f64> = (2..=11).map(|i| 10.0 * i as f64).collect();
    let caps: Vec<f64> = SchemeConfig::fig6_schemes()
        .into_iter()
        .map(|s| capacity_from_curve(&sweep_arrival_rates(&base(), &s, &rates, 2), 0.95))
        .collect();
    let (icc, dis, mec) = (caps[0], caps[1], caps[2]);
    assert!(icc > dis && dis >= mec, "ordering violated: {caps:?}");
    let gain = icc / mec - 1.0;
    assert!((0.25..=1.1).contains(&gain), "ICC gain {:.1}% (paper: 60%)", gain * 100.0);
}

#[test]
fn fig7_compute_savings_reproduced() {
    // ICC needs fewer ×A100 than the disjoint schemes (paper: 8 vs 11).
    let caps: Vec<f64> = (5..=14).map(|i| i as f64).collect();
    let mut b = base();
    b.n_ues = 60;
    let mins: Vec<Option<f64>> = SchemeConfig::fig6_schemes()
        .into_iter()
        .map(|s| min_capacity_from_curve(&sweep_gpu_capacity(&b, &s, &caps, 2), 0.95))
        .collect();
    let icc = mins[0].expect("ICC must reach 95%");
    assert!((6.0..=10.0).contains(&icc), "ICC min capacity {icc} (paper: 8)");
    if let Some(dis) = mins[1] {
        assert!(icc < dis, "ICC {icc} must need less than disjoint {dis}");
        let saving = 1.0 - icc / dis;
        assert!(saving > 0.08, "saving {:.1}% too small", saving * 100.0);
    }
}

#[test]
fn priority_scheme_gain_vanishes_with_abundant_compute() {
    // Paper Fig 7 discussion: as GPU capacity grows, joint-vs-disjoint
    // disparity diminishes.
    let mut b = base();
    b.n_ues = 60;
    let caps = [24.0];
    let icc = sweep_gpu_capacity(&b, &SchemeConfig::icc(), &caps, 2)[0].satisfaction;
    let dis = sweep_gpu_capacity(&b, &SchemeConfig::disjoint_ran(), &caps, 2)[0].satisfaction;
    assert!(icc > 0.97 && dis > 0.93, "icc {icc}, dis {dis}");
    assert!((icc - dis).abs() < 0.06, "gap should be small at 24×A100: {icc} vs {dis}");
}

#[test]
fn satisfaction_decreases_with_load_in_sls() {
    let rates = [20.0, 60.0, 100.0];
    let pts = sweep_arrival_rates(&base(), &SchemeConfig::mec(), &rates, 2);
    assert!(pts[0].satisfaction >= pts[1].satisfaction);
    assert!(pts[1].satisfaction >= pts[2].satisfaction);
}

#[test]
fn comm_latency_grows_with_load() {
    // Fig 6 bar plot: average communication latency climbs with the
    // prompt arrival rate (more PRB contention + queueing).
    let rates = [20.0, 110.0];
    let pts = sweep_arrival_rates(&base(), &SchemeConfig::mec(), &rates, 2);
    assert!(
        pts[1].avg_comm_ms > pts[0].avg_comm_ms,
        "comm {:.2} -> {:.2} ms",
        pts[0].avg_comm_ms,
        pts[1].avg_comm_ms
    );
}

#[test]
fn analytic_and_sls_capacities_same_regime() {
    // The tandem-queue abstraction and the SLS are different models,
    // but both must put the three schemes in the same order and within
    // a factor ~2 of each other's capacity estimates.
    let p = SystemParams::paper();
    let theory: Vec<f64> = Scheme::fig4_schemes()
        .iter()
        .map(|s| {
            service_capacity(
                |l| scheme_satisfaction(&p, s, l),
                0.95,
                p.stability_limit() - 1e-6,
                1e-6,
            )
            .lambda_star
        })
        .collect();
    let rates: Vec<f64> = (2..=11).map(|i| 10.0 * i as f64).collect();
    let sls: Vec<f64> = SchemeConfig::fig6_schemes()
        .into_iter()
        .map(|s| capacity_from_curve(&sweep_arrival_rates(&base(), &s, &rates, 2), 0.95))
        .collect();
    for (t, s) in theory.iter().zip(&sls) {
        let ratio = s / t;
        assert!((0.5..=2.5).contains(&ratio), "theory {t:.1} vs sls {s:.1}");
    }
}

#[test]
fn dropped_jobs_only_under_priority_scheme() {
    let mut cfg = base();
    cfg.n_ues = 100; // overload
    let icc = run_scheme(&cfg, SchemeConfig::icc(), 7);
    let mec = run_scheme(&cfg, SchemeConfig::mec(), 7);
    assert!(icc.n_dropped > 0, "ICC must shed hopeless jobs under overload");
    assert_eq!(mec.n_dropped, 0, "FIFO baseline never drops");
}

#[test]
fn wireline_only_difference_between_ran_and_mec_disjoint() {
    // Same management, same priority config — only the wireline
    // constant differs, so RAN-disjoint must dominate MEC.
    let mut cfg = base();
    cfg.n_ues = 55;
    let ran = run_scheme(&cfg, SchemeConfig::disjoint_ran(), 11);
    let mec = run_scheme(&cfg, SchemeConfig::mec(), 11);
    assert!(
        ran.satisfaction_rate() >= mec.satisfaction_rate() - 0.02,
        "ran {} vs mec {}",
        ran.satisfaction_rate(),
        mec.satisfaction_rate()
    );
}

#[test]
fn gpu_scaling_monotone_in_sls() {
    let mut b = base();
    b.n_ues = 60;
    let caps = [5.0, 9.0, 14.0];
    let pts = sweep_gpu_capacity(&b, &SchemeConfig::icc(), &caps, 2);
    assert!(pts[0].satisfaction <= pts[1].satisfaction + 0.02);
    assert!(pts[1].satisfaction <= pts[2].satisfaction + 0.02);
    // tokens/s also improves with capacity
    assert!(pts[2].avg_tokens_per_sec > pts[0].avg_tokens_per_sec);
}

#[test]
fn sls_event_counter_is_nonzero() {
    // Regression: SlsResult.events used to be hardcoded to 0; it must
    // now carry the EventQueue's popped count.
    let mut cfg = base();
    cfg.n_ues = 20;
    cfg.horizon = 4.0;
    let res = icc6g::sim::Sls::new(cfg.with_scheme(SchemeConfig::icc())).run();
    assert!(res.events > 0, "event counter must be non-zero");
    assert!(
        res.events > res.report.n_jobs,
        "each job takes several events: {} vs {}",
        res.events,
        res.report.n_jobs
    );
}

#[test]
fn a100_capacity_sanity_vs_roofline() {
    // One aggregated pool of g A100s serves ≈ g/0.110 jobs/s; at
    // g = 12 and λ = 60 the system must be comfortably stable.
    let mut cfg = base();
    cfg.n_ues = 60;
    cfg.gpu = GpuSpec::a100().scaled(12.0);
    cfg.n_gpus = 1;
    let r = run_scheme(&cfg, SchemeConfig::icc(), 5);
    assert!(r.satisfaction_rate() > 0.9, "sat = {}", r.satisfaction_rate());
}
