//! Multi-model serving integration tests: the model-zoo invariants.
//!
//! 1. **Legacy bit-identity** — a scenario with no `[[model]]` zoo, and
//!    the same scenario with a one-entry zoo whose model reproduces the
//!    class's single-model constants exactly, yield bit-identical
//!    trajectories — across thread counts {1, 2, 4, 8}.
//! 2. **Quality floor** — a class restricted to an accepted model set
//!    is never priced on a model outside it, whatever the router does;
//!    the per-model report slices bucket accordingly.
//! 3. **Shared-prefix KV reuse** — under a binding KV budget, declaring
//!    a shared prefix strictly increases served capacity.
//! 4. **Swap latency** — the first activation of a cold model charges
//!    the node's swap latency to that job's service, and only that job.

use icc6g::config::SchemeConfig;
use icc6g::llm::{GpuSpec, ModelSpec};
use icc6g::scenario::{
    CellSpec, ExecutionModel, RoutingPolicy, ScenarioBuilder, ScenarioResult,
    ServiceModelKind, TokenDist, WorkloadClass,
};

fn gpu() -> GpuSpec {
    GpuSpec::gh200_nvl2().scaled(2.0)
}

/// The same two-cell, two-node scenario (one sequential node, one
/// continuous-batching node) with and without a one-entry model zoo.
/// The zoo model clones the chat class's single-model constants, so
/// the zoo path must reproduce the legacy path bit for bit.
fn equiv_run(seed: u64, threads: usize, with_zoo: bool) -> ScenarioResult {
    let base = WorkloadClass::chat();
    let class = if with_zoo {
        base.clone().with_models(&["lone"])
    } else {
        base.clone()
    };
    let mut b = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(3.0)
        .warmup(0.5)
        .seed(seed)
        .threads(threads)
        .routing(RoutingPolicy::CellAffinity { spill_queue: u32::MAX })
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(class)
        .cell(CellSpec::new(6))
        .cell(CellSpec::new(6))
        .node(gpu(), 1)
        .node_exec(
            gpu(),
            1,
            ExecutionModel::ContinuousBatching { max_batch: 8, kv_budget: 30e9 },
        );
    if with_zoo {
        b = b.model(
            ModelSpec::new("lone", 7e9)
                .with_c_llm(base.c_llm)
                .with_m_llm(base.m_llm)
                .with_kv_bytes_per_token(base.kv_bytes_per_token)
                .with_resident_bytes(10e9),
        );
    }
    b.build().run()
}

#[test]
fn one_model_zoo_is_bit_identical_to_legacy_across_threads() {
    let legacy = equiv_run(17, 1, false);
    assert!(legacy.report.n_jobs > 20, "n = {}", legacy.report.n_jobs);
    assert!(legacy.report.per_model.is_empty(), "no zoo, no per-model slices");
    for threads in [1usize, 2, 4, 8] {
        let zoo = equiv_run(17, threads, true);
        assert_eq!(legacy.events, zoo.events, "threads = {threads}");
        assert_eq!(legacy.outcomes.len(), zoo.outcomes.len(), "threads = {threads}");
        for (a, b) in legacy.outcomes.iter().zip(&zoo.outcomes) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.class_id, b.class_id);
            assert_eq!(a.cell_id, b.cell_id);
            assert_eq!(a.fate, b.fate, "job {}", a.job_id);
            assert_eq!(a.tokens, b.tokens, "job {}", a.job_id);
            assert_eq!(a.t_gen.to_bits(), b.t_gen.to_bits(), "job {}", a.job_id);
            assert_eq!(a.t_comm.to_bits(), b.t_comm.to_bits(), "job {}", a.job_id);
            assert_eq!(a.t_queue.to_bits(), b.t_queue.to_bits(), "job {}", a.job_id);
            assert_eq!(
                a.t_service.to_bits(),
                b.t_service.to_bits(),
                "job {}",
                a.job_id
            );
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits(), "job {}", a.job_id);
            assert_eq!(a.tpot.to_bits(), b.tpot.to_bits(), "job {}", a.job_id);
            // the only permitted difference: the zoo run tags the model
            assert_eq!(a.model_id, u32::MAX);
            if b.fate != icc6g::metrics::JobFate::InFlight {
                assert_eq!(b.model_id, 0, "job {}", a.job_id);
            }
        }
        // and the zoo run's per-model slice carries the whole run
        assert_eq!(zoo.report.per_model.len(), 1);
        assert_eq!(zoo.report.per_model[0].name, "lone");
        assert_eq!(zoo.report.per_model[0].n_jobs, zoo.report.n_jobs);
    }
}

/// Two-model zoo, split hosting: the premium class only accepts the
/// large model, the bulk class accepts either. Whatever nodes the
/// router picks, no job may ever be priced on a model outside its
/// class's accepted set (the quality floor), and the per-model report
/// slices must bucket exactly by the served model.
#[test]
fn router_never_violates_the_class_quality_floor() {
    let res = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .horizon(4.0)
        .warmup(0.5)
        .seed(5)
        .routing(RoutingPolicy::LeastLoaded)
        .service_kind(ServiceModelKind::TokenSampled)
        .workload(WorkloadClass::chat().with_models(&["70b"]))
        .workload(WorkloadClass::translation().with_models(&["7b", "70b"]))
        .cell(CellSpec::new(20))
        .model(ModelSpec::llama_70b().with_resident_bytes(140e9))
        .model(ModelSpec::llama_7b().with_resident_bytes(14e9))
        .node(GpuSpec::gh200_nvl2().scaled(2.0), 1)
        .node_models(&["70b", "7b"])
        .node_swap_s(0.02)
        .node(GpuSpec::a100().scaled(2.0), 1)
        .node_models(&["7b"])
        .build()
        .run();
    assert!(res.report.n_jobs > 50, "n = {}", res.report.n_jobs);
    // zoo order: 70b = 0, 7b = 1. Jobs still in flight at the horizon
    // (possibly never dispatched) are skipped, as the report does.
    let mut served = [0u64; 2];
    for o in &res.outcomes {
        if o.fate == icc6g::metrics::JobFate::InFlight {
            continue;
        }
        assert_ne!(o.model_id, u32::MAX, "job {}: zoo runs always pick a model", o.job_id);
        served[o.model_id as usize] += 1;
        if o.class_id == 0 {
            assert_eq!(o.model_id, 0, "job {}: premium floor violated", o.job_id);
        }
    }
    assert!(served[0] > 0, "the premium tier served nothing");
    // per-model slices bucket exactly by served model
    assert_eq!(res.report.per_model.len(), 2);
    assert_eq!(res.report.per_model[0].name, "70b");
    assert_eq!(res.report.per_model[1].name, "7b");
    for (k, c) in res.report.per_model.iter().enumerate() {
        assert_eq!(
            c.n_jobs, served[k],
            "model '{}': report slice vs tagged outcomes",
            c.name
        );
    }
}

/// One batching node with a KV budget that admits only ~2 concurrent
/// jobs when every job reserves its full context (576 tokens · 1 MB ≈
/// 0.58 GB against a 1.3 GB budget), capping throughput near 20
/// jobs/s against 36 jobs/s offered. Declaring a 448-token shared
/// prefix collapses per-job reservations to the 128-token suffix
/// (plus one shared block), so the same budget holds ~6 jobs at once
/// and strictly more jobs complete over the same horizon.
#[test]
fn shared_prefix_reuse_increases_served_capacity() {
    let run = |prefix_tokens: u32| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(5.0)
            .warmup(0.5)
            .seed(11)
            .service_kind(ServiceModelKind::TokenSampled)
            .workload(
                WorkloadClass::chat()
                    .with_rate(3.0)
                    .with_input(TokenDist::Fixed(512))
                    .with_output(TokenDist::Fixed(64))
                    .with_budget(2.0)
                    .with_models(&["m"])
                    .with_prefix_tokens(prefix_tokens),
            )
            .cell(CellSpec::new(12))
            .model(
                ModelSpec::new("m", 7e9)
                    .with_kv_bytes_per_token(1e6)
                    .with_resident_bytes(10e9),
            )
            .node_exec(
                gpu(),
                1,
                ExecutionModel::ContinuousBatching { max_batch: 16, kv_budget: 1.3e9 },
            )
            .build()
            .run()
    };
    let without = run(0);
    let with = run(448);
    // identical arrivals; reuse must strictly raise completed work
    // (the budget binds: 0.58 GB/job without reuse, 0.13 GB/job once
    // the 448-token prefix block is shared, under saturating offered
    // load)
    assert!(
        with.report.comp.count() > without.report.comp.count(),
        "prefix reuse served {} vs {} without",
        with.report.comp.count(),
        without.report.comp.count()
    );
    assert!(
        with.report.n_satisfied >= without.report.n_satisfied,
        "reuse cannot lower satisfaction: {} vs {}",
        with.report.n_satisfied,
        without.report.n_satisfied
    );
}

/// The first job to activate a model on a node pays the swap latency
/// in its service time; with a single sequential node and one model
/// that is exactly job 0, and only job 0.
#[test]
fn cold_model_activation_charges_swap_latency_once() {
    let run = |swap_s: f64| {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .horizon(3.0)
            .warmup(0.0)
            .seed(7)
            .service_kind(ServiceModelKind::TokenSampled)
            .workload(WorkloadClass::translation().with_models(&["m"]))
            .cell(CellSpec::new(8))
            .model(ModelSpec::new("m", 7e9).with_resident_bytes(10e9))
            .node(gpu(), 1)
            .node_swap_s(swap_s)
            .build()
            .run()
    };
    let cold = run(0.05);
    let free = run(0.0);
    assert_eq!(cold.outcomes[0].job_id, free.outcomes[0].job_id);
    let d = cold.outcomes[0].t_service - free.outcomes[0].t_service;
    assert!(
        (d - 0.05).abs() < 1e-9,
        "first activation must carry the 50 ms swap, got Δ = {d}"
    );
    // the swap is charged once: later jobs have identical roofline
    // service (queueing may shift, service must not)
    for (a, b) in cold.outcomes.iter().zip(&free.outcomes).skip(1) {
        if a.fate == icc6g::metrics::JobFate::Completed
            && b.fate == icc6g::metrics::JobFate::Completed
        {
            assert_eq!(
                a.t_service.to_bits(),
                b.t_service.to_bits(),
                "job {}: swap leaked into a warm activation",
                a.job_id
            );
        }
    }
    assert!(cold.report.e2e.mean() >= free.report.e2e.mean());
}
