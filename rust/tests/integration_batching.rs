//! Integration tests for the continuous-batching execution model:
//! the `max_batch = 1` ≡ `Sequential` equivalence property, the
//! throughput win on a saturated node (the ISSUE acceptance bar), and
//! per-class accounting through a batching tier.

use icc6g::config::{Deployment, Management, SchemeConfig};
use icc6g::llm::{CostModel, GpuSpec, JobSpec};
use icc6g::metrics::JobFate;
use icc6g::prop_assert;
use icc6g::scenario::{
    ExecutionModel, ScenarioBuilder, ScenarioResult, ServiceModelKind, TokenDist,
    WorkloadClass,
};
use icc6g::util::proptest::check;

fn joint_ran(priority: bool) -> SchemeConfig {
    SchemeConfig::builder()
        .name("joint RAN")
        .deployment(Deployment::Ran)
        .management(Management::Joint)
        .priority(priority)
        .build()
}

/// (fate, e2e) per measured job, in job-id order.
fn per_job(res: &ScenarioResult) -> Vec<(JobFate, f64)> {
    res.outcomes.iter().map(|o| (o.fate, o.e2e())).collect()
}

#[test]
fn batch_of_one_is_the_sequential_node() {
    // Property: across random small scenarios (random load, output
    // lengths, budgets, and discipline), ContinuousBatching with
    // max_batch = 1 produces the same per-job fates and completion
    // times as the Sequential node (within f64 accumulation noise —
    // the batch engine sums per-iteration boundaries while the
    // sequential node adds one service time).
    check(6, |g| {
        let seed = g.u64_below(1000);
        let n_ues = g.usize_range(2, 6) as u32;
        let rate = g.f64_range(0.3, 2.0);
        let out_mean = g.f64_range(2.0, 24.0);
        let budget = g.f64_range(0.1, 0.6);
        let priority = g.bool(0.5);
        let class = WorkloadClass::translation()
            .with_rate(rate)
            .with_output(TokenDist::Geometric { mean: out_mean })
            .with_budget(budget);
        let build = |exec: ExecutionModel| {
            ScenarioBuilder::new()
                .scheme(joint_ran(priority))
                .n_ues(n_ues)
                .horizon(2.0)
                .warmup(0.2)
                .seed(seed)
                .workload(class.clone())
                .service_kind(ServiceModelKind::TokenSampled)
                .node_exec(GpuSpec::gh200_nvl2(), 1, exec)
                .build()
                .run()
        };
        let seq = build(ExecutionModel::Sequential);
        let bat = build(ExecutionModel::ContinuousBatching {
            max_batch: 1,
            kv_budget: 0.0,
        });
        let (a, b) = (per_job(&seq), per_job(&bat));
        prop_assert!(a.len() == b.len(), "job counts differ: {} vs {}", a.len(), b.len());
        for (i, ((fa, ea), (fb, eb))) in a.iter().zip(&b).enumerate() {
            prop_assert!(fa == fb, "job {i}: fate {fa:?} vs {fb:?}");
            if *fa == JobFate::Completed {
                prop_assert!(
                    (ea - eb).abs() < 1e-6,
                    "job {i}: e2e {ea} vs {eb} (Δ {})",
                    (ea - eb).abs()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn wide_batching_outserves_sequential_on_saturated_node() {
    // ISSUE acceptance: with max_batch ≥ the saturation batch, a
    // continuous-batching node sustains strictly higher throughput
    // than the sequential node on a saturated single-node scenario.
    let sat = CostModel::new(GpuSpec::a100()).saturation_batch(&JobSpec::table1());
    let run = |exec: ExecutionModel| {
        ScenarioBuilder::new()
            .scheme(joint_ran(false))
            .n_ues(40) // 40 jobs/s vs ≈9 jobs/s sequential capacity
            .horizon(8.0)
            .warmup(1.0)
            .seed(3)
            .workload(WorkloadClass::translation().with_budget(0.5))
            .node_exec(GpuSpec::a100(), 1, exec)
            .build()
            .run()
    };
    let seq = run(ExecutionModel::Sequential);
    let bat = run(ExecutionModel::ContinuousBatching {
        max_batch: sat.max(160),
        kv_budget: 0.0,
    });
    let served_seq = seq.report.comp.count();
    let served_bat = bat.report.comp.count();
    assert!(
        served_bat > served_seq,
        "batching served {served_bat} vs sequential {served_seq}"
    );
    // and not marginally: the sequential node is saturated, batching
    // keeps up with the offered load
    assert!(
        served_bat as f64 > 2.0 * served_seq as f64,
        "batching {served_bat} should far exceed sequential {served_seq}"
    );
    assert!(
        bat.report.satisfaction_rate() > seq.report.satisfaction_rate(),
        "satisfaction {} vs {}",
        bat.report.satisfaction_rate(),
        seq.report.satisfaction_rate()
    );
}

#[test]
fn batching_tier_keeps_per_class_accounting() {
    // A mixed-class scenario over one batching node: per-class slices
    // still sum to the overall report and TTFT is recorded from real
    // iteration boundaries (positive, below E2E).
    let res = ScenarioBuilder::new()
        .scheme(SchemeConfig::icc())
        .n_ues(20)
        .horizon(6.0)
        .warmup(1.0)
        .seed(5)
        .workload(WorkloadClass::translation())
        .workload(WorkloadClass::chat())
        .service_kind(ServiceModelKind::TokenSampled)
        .node_exec(
            GpuSpec::gh200_nvl2().scaled(2.0),
            1,
            ExecutionModel::ContinuousBatching { max_batch: 32, kv_budget: 0.0 },
        )
        .build()
        .run();
    assert!(res.report.n_jobs > 30, "n = {}", res.report.n_jobs);
    assert!(res.report.comp.count() > 0, "nothing served");
    let sum: u64 = res.report.per_class.iter().map(|c| c.n_jobs).sum();
    assert_eq!(sum, res.report.n_jobs);
    for o in res.outcomes.iter().filter(|o| o.fate == JobFate::Completed) {
        assert!(o.ttft > 0.0, "job {}: ttft must be positive", o.job_id);
        assert!(
            o.ttft <= o.e2e() + 1e-12,
            "job {}: ttft {} beyond e2e {}",
            o.job_id,
            o.ttft,
            o.e2e()
        );
        assert!(o.tpot >= 0.0);
    }
    for c in &res.report.per_class {
        assert_eq!(c.ttft.count(), c.comp.count(), "class '{}'", c.name);
    }
}

#[test]
fn deterministic_given_seed_with_batching() {
    let build = || {
        ScenarioBuilder::new()
            .scheme(SchemeConfig::icc())
            .n_ues(15)
            .horizon(4.0)
            .warmup(0.5)
            .seed(17)
            .workload(WorkloadClass::chat())
            .service_kind(ServiceModelKind::TokenSampled)
            .node_exec(
                GpuSpec::gh200_nvl2(),
                1,
                ExecutionModel::ContinuousBatching { max_batch: 16, kv_budget: 0.0 },
            )
            .build()
            .run()
    };
    let a = build();
    let b = build();
    assert_eq!(a.report.n_jobs, b.report.n_jobs);
    assert_eq!(a.report.n_satisfied, b.report.n_satisfied);
    assert_eq!(a.events, b.events);
    assert!((a.report.ttft.mean() - b.report.ttft.mean()).abs() < 1e-15);
}
