"""L2 model tests: shapes, prefill/decode consistency, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (ModelConfig, decode, flatten_params,
                           generate_greedy, init_params, param_order,
                           prefill, unflatten_params)

CFG = ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4, head_dim=16,
                  d_ffn=96, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def flat(params):
    return flatten_params(CFG, params)


def test_param_order_shapes(params):
    for name, shape in param_order(CFG):
        assert params[name].shape == shape, name


def test_n_params_counts_everything(params):
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == CFG.n_params


def test_unflatten_roundtrip(params, flat):
    back = unflatten_params(CFG, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_prefill_shapes(flat):
    toks = jnp.zeros((CFG.max_seq,), jnp.int32)
    logits, kc, vc = prefill(CFG, flat, toks)
    assert logits.shape == (CFG.max_seq, CFG.vocab)
    assert kc.shape == (CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert vc.shape == kc.shape
    assert np.isfinite(np.asarray(logits)).all()


def test_decode_shapes(flat):
    toks = jnp.zeros((CFG.max_seq,), jnp.int32)
    _, kc, vc = prefill(CFG, flat, toks)
    logits, kc2, vc2 = decode(CFG, flat, jnp.array([3], jnp.int32),
                              jnp.array([5], jnp.int32), kc, vc)
    assert logits.shape == (CFG.vocab,)
    assert kc2.shape == kc.shape and vc2.shape == vc.shape


def test_prefill_causality_padding_invariance(flat):
    """Padding tokens beyond n_input must not change logits before it."""
    n = 10
    body = jnp.arange(n, dtype=jnp.int32) % CFG.vocab
    t1 = jnp.zeros((CFG.max_seq,), jnp.int32).at[:n].set(body)
    t2 = t1.at[n:].set(7)  # different padding
    l1, _, _ = prefill(CFG, flat, t1)
    l2, _, _ = prefill(CFG, flat, t2)
    np.testing.assert_allclose(l1[:n], l2[:n], rtol=1e-5, atol=1e-5)


def test_decode_reproduces_prefill_logits(flat):
    """Feeding tokens one-by-one through decode must reproduce prefill's
    per-position logits (the KV-cache path equals the parallel path)."""
    n = 8
    toks = (jnp.arange(n, dtype=jnp.int32) * 3 + 1) % CFG.vocab
    padded = jnp.zeros((CFG.max_seq,), jnp.int32).at[:n].set(toks)
    ref_logits, _, _ = prefill(CFG, flat, padded)

    kc = jnp.zeros((CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    for i in range(n):
        lg, kc, vc = decode(CFG, flat, toks[i:i + 1],
                            jnp.array([i], jnp.int32), kc, vc)
        np.testing.assert_allclose(lg, ref_logits[i], rtol=5e-4, atol=5e-4)


def test_decode_updates_only_its_position(flat):
    kc = jnp.full((CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim), 9.0)
    vc = jnp.full_like(kc, -9.0)
    pos = 4
    _, kc2, vc2 = decode(CFG, flat, jnp.array([1], jnp.int32),
                         jnp.array([pos], jnp.int32), kc, vc)
    mask = np.ones(CFG.max_seq, bool)
    mask[pos] = False
    np.testing.assert_array_equal(np.asarray(kc2)[:, :, mask, :],
                                  np.asarray(kc)[:, :, mask, :])
    assert not np.array_equal(np.asarray(kc2)[:, :, pos, :],
                              np.asarray(kc)[:, :, pos, :])


def test_generate_greedy_deterministic(params):
    out1 = generate_greedy(CFG, params, [1, 2, 3], 6)
    out2 = generate_greedy(CFG, params, [1, 2, 3], 6)
    assert out1 == out2
    assert len(out1) == 6
    assert all(0 <= t < CFG.vocab for t in out1)


def test_init_params_seed_determinism():
    a = init_params(CFG, seed=1)
    b = init_params(CFG, seed=1)
    c = init_params(CFG, seed=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a
               if not k.startswith("norm"))


def test_rope_position_dependence(flat):
    """Causal attention over the *set* {5,6} is order-invariant without
    positional encoding; RoPE must break that symmetry, so the logits at
    position 1 of [5,6,...] and [6,5,...] must differ."""
    ta = jnp.zeros((CFG.max_seq,), jnp.int32).at[0].set(5).at[1].set(6)
    tb = jnp.zeros((CFG.max_seq,), jnp.int32).at[0].set(6).at[1].set(5)
    la, _, _ = prefill(CFG, flat, ta)
    lb, _, _ = prefill(CFG, flat, tb)
    assert not np.allclose(la[1], lb[1], rtol=1e-3)
