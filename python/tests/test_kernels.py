"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes/dtypes and asserts allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, flash_attention
from compile.kernels.rmsnorm import rmsnorm

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- flash

@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4, 8]),
    s=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(h, s, d, seed):
    key = jax.random.PRNGKey(seed)
    q, k, v = (_rand(jax.random.fold_in(key, i), (h, s, d), jnp.float32)
               for i in range(3))
    out = flash_attention(q, k, v, causal=True)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, **TOL[jnp.float32])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_flash_attention_bf16(seed):
    key = jax.random.PRNGKey(seed)
    q, k, v = (_rand(jax.random.fold_in(key, i), (4, 64, 32), jnp.bfloat16)
               for i in range(3))
    out = flash_attention(q, k, v)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out.astype(np.float32),
                               exp.astype(np.float32), **TOL[jnp.bfloat16])


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (16, 32), (32, 16),
                                             (32, 64), (64, 32)])
def test_flash_attention_block_shapes(block_q, block_k):
    """Result must be invariant to the tiling — pure schedule change."""
    key = jax.random.PRNGKey(7)
    q, k, v = (_rand(jax.random.fold_in(key, i), (2, 64, 32), jnp.float32)
               for i in range(3))
    out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, **TOL[jnp.float32])


def test_flash_attention_non_causal():
    key = jax.random.PRNGKey(3)
    q, k, v = (_rand(jax.random.fold_in(key, i), (2, 32, 16), jnp.float32)
               for i in range(3))
    out = flash_attention(q, k, v, causal=False)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, **TOL[jnp.float32])


def test_flash_attention_rejects_ragged_seq():
    q = jnp.zeros((1, 48, 16))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=32, block_k=32)


def test_flash_attention_first_row_is_v0():
    """Causal row 0 attends only to position 0 → output == v[:, 0]."""
    key = jax.random.PRNGKey(11)
    q, k, v = (_rand(jax.random.fold_in(key, i), (3, 32, 16), jnp.float32)
               for i in range(3))
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------- decode

@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 4, 8]),
    s_max=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([16, 32]),
    frac=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(h, s_max, d, frac, seed):
    key = jax.random.PRNGKey(seed)
    cur_len = max(1, int(frac * s_max))
    q = _rand(key, (h, d), jnp.float32)
    kc = _rand(jax.random.fold_in(key, 1), (h, s_max, d), jnp.float32)
    vc = _rand(jax.random.fold_in(key, 2), (h, s_max, d), jnp.float32)
    out = decode_attention(q, kc, vc, cur_len)
    exp = ref.decode_attention_ref(q, kc, vc, cur_len)
    np.testing.assert_allclose(out, exp, **TOL[jnp.float32])


def test_decode_attention_ignores_stale_cache():
    """Rows >= cur_len must not affect the output."""
    key = jax.random.PRNGKey(5)
    h, s_max, d, cur = 2, 32, 16, 9
    q = _rand(key, (h, d), jnp.float32)
    kc = _rand(jax.random.fold_in(key, 1), (h, s_max, d), jnp.float32)
    vc = _rand(jax.random.fold_in(key, 2), (h, s_max, d), jnp.float32)
    out1 = decode_attention(q, kc, vc, cur)
    kc2 = kc.at[:, cur:, :].set(1e6)
    vc2 = vc.at[:, cur:, :].set(-1e6)
    out2 = decode_attention(q, kc2, vc2, cur)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_len1_returns_v0():
    key = jax.random.PRNGKey(6)
    q = _rand(key, (4, 32), jnp.float32)
    kc = _rand(jax.random.fold_in(key, 1), (4, 64, 32), jnp.float32)
    vc = _rand(jax.random.fold_in(key, 2), (4, 64, 32), jnp.float32)
    out = decode_attention(q, kc, vc, 1)
    np.testing.assert_allclose(out, vc[:, 0, :], rtol=1e-6, atol=1e-6)


def test_decode_matches_last_row_of_flash():
    """Decode with a full cache == last causal row of prefill attention."""
    key = jax.random.PRNGKey(8)
    h, s, d = 4, 32, 16
    q, k, v = (_rand(jax.random.fold_in(key, i), (h, s, d), jnp.float32)
               for i in range(3))
    full = flash_attention(q, k, v, causal=True)
    last = decode_attention(q[:, -1, :], k, v, s)
    np.testing.assert_allclose(last, full[:, -1, :], rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- rmsnorm

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 4, 32, 64]),
    d=st.sampled_from([16, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(n, d, seed):
    key = jax.random.PRNGKey(seed)
    x = _rand(key, (n, d), jnp.float32)
    w = _rand(jax.random.fold_in(key, 1), (d,), jnp.float32)
    np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm_ref(x, w),
                               **TOL[jnp.float32])


def test_rmsnorm_1d_input():
    key = jax.random.PRNGKey(2)
    x = _rand(key, (128,), jnp.float32)
    w = jnp.ones((128,))
    out = rmsnorm(x, w)
    assert out.shape == (128,)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, w), **TOL[jnp.float32])


def test_rmsnorm_unit_output_scale():
    """With w=1, the RMS of the output is ~1."""
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 256)) * 7.3
    out = rmsnorm(x, jnp.ones((256,)))
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


def test_rmsnorm_scale_invariance():
    """RMSNorm(c*x) == RMSNorm(x) for c > 0 (up to eps)."""
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(10), (128,))
    a = rmsnorm(x, w)
    b = rmsnorm(100.0 * x, w)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
