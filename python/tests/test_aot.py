"""AOT export tests: HLO text validity, weights.bin format, golden trace."""

import io
import os
import struct
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig, init_params, param_order

CFG = ModelConfig(vocab=64, d_model=64, n_layers=2, n_heads=4, head_dim=16,
                  d_ffn=96, max_seq=32)


def test_to_hlo_text_roundtrip_simple():
    """A trivial jitted fn must lower to parseable HLO text with ENTRY."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_write_weights_format(tmp_path):
    params = init_params(CFG, seed=0)
    path = tmp_path / "w.bin"
    aot.write_weights(str(path), CFG, params)
    data = path.read_bytes()
    buf = io.BytesIO(data)
    assert buf.read(4) == b"ICCW"
    version, n = struct.unpack("<II", buf.read(8))
    assert version == 1
    assert n == len(param_order(CFG))
    for name, shape in param_order(CFG):
        (nlen,) = struct.unpack("<I", buf.read(4))
        assert buf.read(nlen).decode() == name
        (rank,) = struct.unpack("<I", buf.read(4))
        dims = struct.unpack(f"<{rank}I", buf.read(4 * rank))
        assert dims == shape
        nel = int(np.prod(shape))
        arr = np.frombuffer(buf.read(4 * nel), dtype="<f4").reshape(shape)
        np.testing.assert_allclose(arr, np.asarray(params[name]), rtol=0,
                                   atol=0)
    assert buf.read() == b""  # no trailing bytes


def test_byte_tokenize():
    toks = aot.byte_tokenize("ab")
    assert toks == [256, 97, 98]
    assert all(0 <= t < 512 for t in toks)


def test_byte_tokenize_utf8_multibyte():
    toks = aot.byte_tokenize("é")  # 2-byte utf-8
    assert len(toks) == 3
    assert toks[0] == 256


@pytest.mark.slow
def test_full_export_artifacts_exist():
    """make artifacts must have produced every artifact (run after make)."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("artifacts/ not built yet (run `make artifacts`)")
    for f in ["prefill.hlo.txt", "decode.hlo.txt", "weights.bin",
              "model_meta.txt", "golden_trace.txt"]:
        path = os.path.join(art, f)
        assert os.path.exists(path), f
        assert os.path.getsize(path) > 0, f
    with open(os.path.join(art, "prefill.hlo.txt")) as fh:
        assert "ENTRY" in fh.read()
    with open(os.path.join(art, "golden_trace.txt")) as fh:
        lines = fh.read().strip().splitlines()
    assert lines[0].startswith("prompt ") and lines[1].startswith("output ")
    out_toks = [int(x) for x in lines[1].split()[1:]]
    assert len(out_toks) == aot.N_GOLDEN_OUTPUT
