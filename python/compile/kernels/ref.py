"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package must match its oracle to numerical tolerance (see
python/tests/test_kernel.py, which sweeps shapes and dtypes with
hypothesis). Keep these implementations maximally simple — no tiling,
no tricks — so that a mismatch always indicts the kernel, not the ref.
"""

import jax.numpy as jnp


def rmsnorm_ref(x, w, eps: float = 1e-5):
    """RMSNorm: x * w / sqrt(mean(x^2) + eps), normalized over last axis."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """Multi-head attention oracle.

    q, k, v: [H, S, D].  Returns [H, S, D].
    Causal mask applied if `causal`; softmax in float32.
    """
    h, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum(
        "hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, cur_len):
    """Single-token decode attention oracle.

    q: [H, D] query for the current position.
    k_cache, v_cache: [H, S_max, D]; only positions < cur_len are valid.
    cur_len: scalar int (number of valid cache entries, including the
    current token's KV which the caller has already written).
    Returns [H, D].
    """
    h, s_max, d = k_cache.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum(
        "hd,hsd->hs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    valid = jnp.arange(s_max) < cur_len
    logits = jnp.where(valid[None, :], logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hs,hsd->hd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP oracle: down( silu(x@gate) * (x@up) )."""
    x32 = x.astype(jnp.float32)
    g = x32 @ w_gate.astype(jnp.float32)
    u = x32 @ w_up.astype(jnp.float32)
    act = g * (1.0 / (1.0 + jnp.exp(-g)))  # silu
    out = (act * u) @ w_down.astype(jnp.float32)
    return out.astype(x.dtype)
