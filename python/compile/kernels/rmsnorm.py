"""L1 Pallas fused RMSNorm kernel.

Row-parallel RMSNorm over the last axis: each grid step normalizes one
block of rows entirely in "VMEM" (one HBM read + one HBM write per
element — the memory-bound optimum). float32 statistics regardless of
input dtype. Oracle: kernels.ref.rmsnorm_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # [block_rows, d]
    w = w_ref[...].astype(jnp.float32)                  # [d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps=1e-5, block_rows=32, interpret=True):
    """Fused RMSNorm. x: [N, D] (or [D]); w: [D]. Returns x.dtype."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    n, d = x.shape
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"rows {n} not divisible by block_rows {block_rows}")
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[0] if squeeze else out
