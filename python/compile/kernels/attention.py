"""L1 Pallas attention kernels (prefill flash-attention + single-token decode).

TPU-idiomatic structure, CPU-interpretable execution:

* Tiling is expressed with ``BlockSpec`` — the HBM→VMEM schedule a CUDA
  flash-attention would express with threadblocks + shared memory. Each
  grid step sees one (head, q-block) tile in "VMEM" and streams K/V
  blocks with an online-softmax accumulator.
* All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
  cannot execute Mosaic custom-calls, and interpret mode lowers to plain
  HLO ops that the Rust runtime (xla crate, PJRT CPU) can run. Real-TPU
  perf is therefore *estimated* from the block geometry (see DESIGN.md
  §Hardware-Adaptation), not measured.
* Numerics: logits/softmax/accumulation in float32 regardless of input
  dtype (bfloat16 inputs are upcast per-tile, as the MXU would).

Correctness oracle: kernels.ref.attention_ref / decode_attention_ref.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Finite stand-in for -inf: keeps the online-softmax update NaN-free on
# fully-masked tiles (exp(NEG_BIG - NEG_BIG) would be exp(0) only if a
# row's running max never left NEG_BIG, which cannot happen for causal
# attention because column 0 is always visible to every row).
NEG_BIG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq,
                  scale, causal):
    """One (head, q-block) grid step of causal flash attention."""
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # [block_q, d]
    d = q.shape[-1]

    m = jnp.full((block_q,), NEG_BIG, jnp.float32)      # running row max
    l = jnp.zeros((block_q,), jnp.float32)              # running denom
    acc = jnp.zeros((block_q, d), jnp.float32)          # running numerator

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    num_kblocks = seq // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        logits = q @ k.T                                # [block_q, block_k]
        if causal:
            cols = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            logits = jnp.where(rows >= cols, logits, NEG_BIG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m - m_new)                      # rescale old state
        p = jnp.exp(logits - m_new[:, None])
        # Masked entries: exp(NEG_BIG - m_new) underflows to exactly 0.
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # NOTE(tpu-perf): a production Mosaic kernel would bound this loop at
    # the causal frontier (j <= qi); interpret mode keeps the full range
    # for structural simplicity — masked tiles contribute exact zeros.
    m, l, acc = jax.lax.fori_loop(0, num_kblocks, body, (m, l, acc))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=32, block_k=32,
                    interpret=True):
    """Causal multi-head flash attention.

    q, k, v: [H, S, D] with S divisible by both block sizes.
    Returns [H, S, D] in q.dtype.
    """
    h, s, d = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} not divisible by blocks ({block_q},{block_k})")
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq=s, scale=scale,
        causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, i: (hh, i, 0)),
            pl.BlockSpec((None, s, d), lambda hh, i: (hh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hh, i: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hh, i: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, s_max, scale):
    """One head of single-query decode attention over the KV cache."""
    cur_len = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * scale          # [d]
    k = k_ref[...].astype(jnp.float32)                  # [s_max, d]
    v = v_ref[...].astype(jnp.float32)                  # [s_max, d]
    logits = k @ q                                      # [s_max]
    valid = jax.lax.iota(jnp.int32, s_max) < cur_len
    logits = jnp.where(valid, logits, NEG_BIG)
    m = jnp.max(logits)
    p = jnp.exp(logits - m)
    out = (p @ v) / jnp.sum(p)
    o_ref[...] = out.astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, interpret=True):
    """Single-token decode attention.

    q: [H, D]; k_cache, v_cache: [H, S_max, D]; cur_len: scalar int32
    (number of valid cache rows). Returns [H, D].
    """
    h, s_max, d = k_cache.shape
    scale = 1.0 / math.sqrt(d)
    cur_len_arr = jnp.reshape(cur_len, (1,)).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, s_max=s_max, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hh: (0,)),
            pl.BlockSpec((None, d), lambda hh: (hh, 0)),
            pl.BlockSpec((None, s_max, d), lambda hh: (hh, 0, 0)),
            pl.BlockSpec((None, s_max, d), lambda hh: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda hh: (hh, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), q.dtype),
        interpret=interpret,
    )(cur_len_arr, q, k_cache, v_cache)
