"""AOT export: lower the L2 model to HLO *text* + dump weights/metadata.

Outputs (under ``artifacts/``, built once by ``make artifacts``; Python
never runs on the request path):

* ``prefill.hlo.txt`` / ``decode.hlo.txt`` — HLO text of the jitted
  prefill / decode functions. HLO **text** (not ``.serialize()``) is the
  interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
  instruction ids that the xla crate's xla_extension 0.5.1 rejects
  (``proto.id() <= INT_MAX``); the text parser reassigns ids and
  round-trips cleanly. See /opt/xla-example/README.md.
* ``weights.bin`` — little-endian binary of all parameters in
  ``model.param_order()`` order (format below), loaded by
  rust/src/runtime/weights.rs.
* ``model_meta.txt`` — ``key value`` lines with the architecture config
  so the Rust runtime can size its buffers without reparsing HLO.
* ``golden_trace.txt`` — prompt token ids + greedy continuation, used by
  the Rust integration test to prove bit-exact cross-language serving.

weights.bin format:
  magic  b"ICCW"  | u32 version=1 | u32 n_tensors
  per tensor: u32 name_len | name (utf-8) | u32 rank | u32 dims[rank]
              | f32 data (row-major)
"""

import argparse
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (ModelConfig, decode, flatten_params, generate_greedy,
                    init_params, param_order, prefill)

GOLDEN_PROMPT = "The 6G network integrates communication and computing."
N_GOLDEN_OUTPUT = 15  # matches Table I output prompt size


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def write_weights(path, cfg, params):
    names = [n for n, _ in param_order(cfg)]
    with open(path, "wb") as f:
        f.write(b"ICCW")
        f.write(struct.pack("<II", 1, len(names)))
        for name in names:
            arr = jax.device_get(params[name]).astype("float32")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def byte_tokenize(text: str, bos: int = 256):
    """Byte-level tokenizer mirrored by rust/src/runtime/tokenizer.rs."""
    return [bos] + [b for b in text.encode("utf-8")]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = ModelConfig()
    params = init_params(cfg, seed=args.seed)
    flat = flatten_params(cfg, params)
    flat_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat]

    # NOTE(xla-0.5.1): the rust side cannot read multi-element tuple
    # outputs (PjRtBuffer::ToLiteralSync CHECK-fails on tuple shapes
    # with >1 leaf; 1-tuples work — see /opt/xla-example). We therefore
    # export wrappers returning ONE concatenated f32 vector
    # [logits | k_cache | v_cache]; rust/src/runtime/engine.rs splits
    # it at the offsets derived from model_meta.txt.
    def prefill_flat(f, t):
        logits, k, v = prefill(cfg, f, t)
        return (jnp.concatenate(
            [logits.reshape(-1), k.reshape(-1), v.reshape(-1)]),)

    def decode_flat(f, t, p, kc, vc):
        logits, k, v = decode(cfg, f, t, p, kc, vc)
        return (jnp.concatenate(
            [logits.reshape(-1), k.reshape(-1), v.reshape(-1)]),)

    # --- prefill ---
    tok_spec = jax.ShapeDtypeStruct((cfg.max_seq,), jnp.int32)
    lowered = jax.jit(prefill_flat).lower(flat_specs, tok_spec)
    text = to_hlo_text(lowered)
    with open(os.path.join(args.out_dir, "prefill.hlo.txt"), "w") as f:
        f.write(text)
    print(f"prefill.hlo.txt: {len(text)} chars")

    # --- decode ---
    i1 = jax.ShapeDtypeStruct((1,), jnp.int32)
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
    lowered = jax.jit(decode_flat).lower(flat_specs, i1, i1, kv, kv)
    text = to_hlo_text(lowered)
    with open(os.path.join(args.out_dir, "decode.hlo.txt"), "w") as f:
        f.write(text)
    print(f"decode.hlo.txt: {len(text)} chars")

    # --- weights + metadata ---
    write_weights(os.path.join(args.out_dir, "weights.bin"), cfg, params)
    with open(os.path.join(args.out_dir, "model_meta.txt"), "w") as f:
        for k, v in [("vocab", cfg.vocab), ("d_model", cfg.d_model),
                     ("n_layers", cfg.n_layers), ("n_heads", cfg.n_heads),
                     ("head_dim", cfg.head_dim), ("d_ffn", cfg.d_ffn),
                     ("max_seq", cfg.max_seq), ("seed", args.seed),
                     ("n_params", cfg.n_params)]:
            f.write(f"{k} {v}\n")

    # --- golden trace for the Rust integration test ---
    prompt = byte_tokenize(GOLDEN_PROMPT)[: cfg.max_seq - N_GOLDEN_OUTPUT]
    out = generate_greedy(cfg, params, prompt, N_GOLDEN_OUTPUT)
    with open(os.path.join(args.out_dir, "golden_trace.txt"), "w") as f:
        f.write("prompt " + " ".join(map(str, prompt)) + "\n")
        f.write("output " + " ".join(map(str, out)) + "\n")
    print(f"golden trace: {len(prompt)} prompt -> {len(out)} output tokens")
    print(f"model: {cfg.n_params/1e6:.2f}M params")


if __name__ == "__main__":
    main()
