"""L2: tiny Llama-2-style transformer (JAX), calling the L1 Pallas kernels.

This is the *served* model of the reproduction: the paper evaluates
Llama-2-7B with an analytic roofline latency model (Eqs 7-8, implemented
in rust/src/llm/); the serving stack itself runs this ~6M-parameter
architectural twin end-to-end (RMSNorm + RoPE + causal MHA + SwiGLU),
AOT-lowered to HLO text and executed from the Rust coordinator via PJRT.

Two entry points, both fixed-shape for AOT export:

* ``prefill(flat_params, tokens[S_max])`` → (logits[S_max, V],
  k_cache[L, H, S_max, Dh], v_cache[...]) — processes the (padded)
  prompt; causality guarantees positions < n_input are unaffected by
  padding, and decode masks cache rows >= cur_len.
* ``decode(flat_params, token[1], pos[1], k_cache, v_cache)`` →
  (logits[V], k_cache', v_cache') — one autoregressive step; writes the
  new KV at ``pos`` and attends over ``pos+1`` rows.

Weights are runtime inputs (NOT baked into the HLO) so the artifacts
stay small; aot.py exports them to ``artifacts/weights.bin`` in the
order given by ``param_order()`` and the Rust runtime feeds them back as
PJRT literals in that same order.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention, decode_attention
from .kernels.rmsnorm import rmsnorm


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters of the tiny Llama."""
    vocab: int = 512          # byte-level tokens + specials (see tokenizer)
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    head_dim: int = 32        # n_heads * head_dim == d_model
    d_ffn: int = 704          # SwiGLU hidden (~8/3 * d_model, mult of 32)
    max_seq: int = 64
    rope_theta: float = 10000.0

    @property
    def n_params(self) -> int:
        c = self
        per_layer = 4 * c.d_model * c.d_model + 3 * c.d_model * c.d_ffn \
            + 2 * c.d_model
        return (c.vocab * c.d_model * 2 + c.n_layers * per_layer + c.d_model)


def param_order(cfg: ModelConfig):
    """Canonical (name, shape) list — defines weights.bin and HLO arg order."""
    c = cfg
    L, D, F, H, Dh, V = (c.n_layers, c.d_model, c.d_ffn, c.n_heads,
                         c.head_dim, c.vocab)
    return [
        ("embed", (V, D)),
        ("wq", (L, D, H * Dh)),
        ("wk", (L, D, H * Dh)),
        ("wv", (L, D, H * Dh)),
        ("wo", (L, H * Dh, D)),
        ("w_gate", (L, D, F)),
        ("w_up", (L, D, F)),
        ("w_down", (L, F, D)),
        ("norm_attn", (L, D)),
        ("norm_mlp", (L, D)),
        ("norm_f", (D,)),
        ("unembed", (D, V)),
    ]


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic scaled-normal init, returned as a name→array dict."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for i, (name, shape) in enumerate(param_order(cfg)):
        k = jax.random.fold_in(key, i)
        if name.startswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (jax.random.normal(k, shape, jnp.float32)
                            * (1.0 / jnp.sqrt(fan_in)))
    return params


def flatten_params(cfg: ModelConfig, params):
    return [params[name] for name, _ in param_order(cfg)]


def unflatten_params(cfg: ModelConfig, flat):
    return {name: arr for (name, _), arr in zip(param_order(cfg), flat)}


def _rope_tables(cfg: ModelConfig):
    """cos/sin tables [S_max, Dh/2] (constants folded into the HLO)."""
    half = cfg.head_dim // 2
    inv_freq = 1.0 / (cfg.rope_theta
                      ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(cfg.max_seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv_freq)                         # [S, half]
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    """Rotate pairs (x0, x1) of the head dim. x: [..., S, Dh] with
    cos/sin broadcastable [S, Dh/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attn_prefill(cfg, x, wq, wk, wv, wo, cos, sin):
    """Causal MHA over the full (padded) sequence via the flash kernel."""
    s, d = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(s, H, Dh).transpose(1, 0, 2)    # [H, S, Dh]
    k = (x @ wk).reshape(s, H, Dh).transpose(1, 0, 2)
    v = (x @ wv).reshape(s, H, Dh).transpose(1, 0, 2)
    q = _apply_rope(q, cos[None], sin[None])
    k = _apply_rope(k, cos[None], sin[None])
    o = flash_attention(q, k, v, causal=True,
                        block_q=min(32, s), block_k=min(32, s))
    o = o.transpose(1, 0, 2).reshape(s, H * Dh) @ wo
    return o, k, v


def _mlp(x, w_gate, w_up, w_down):
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


def prefill(cfg: ModelConfig, flat_params, tokens):
    """Process a padded prompt. tokens: int32[S_max].

    Returns (logits[S_max, V], k_cache[L,H,S_max,Dh], v_cache[...]).
    """
    p = unflatten_params(cfg, flat_params)
    cos, sin = _rope_tables(cfg)
    x = p["embed"][tokens]                               # [S, D]

    def layer(x, ws):
        (wq, wk, wv, wo, wg, wu, wd, na, nm) = ws
        h, k, v = _attn_prefill(cfg, rmsnorm(x, na), wq, wk, wv, wo, cos, sin)
        x = x + h
        x = x + _mlp(rmsnorm(x, nm), wg, wu, wd)
        return x, (k, v)

    xs = (p["wq"], p["wk"], p["wv"], p["wo"], p["w_gate"], p["w_up"],
          p["w_down"], p["norm_attn"], p["norm_mlp"])
    x, (k_cache, v_cache) = jax.lax.scan(layer, x, xs)
    logits = rmsnorm(x, p["norm_f"]) @ p["unembed"]
    return logits, k_cache, v_cache


def decode(cfg: ModelConfig, flat_params, token, pos, k_cache, v_cache):
    """One autoregressive step.

    token: int32[1]; pos: int32[1] (the position this token occupies);
    caches: [L, H, S_max, Dh]. Returns (logits[V], k_cache', v_cache').
    """
    p = unflatten_params(cfg, flat_params)
    cos, sin = _rope_tables(cfg)
    H, Dh = cfg.n_heads, cfg.head_dim
    pos_s = pos[0]
    cos_p = jax.lax.dynamic_slice_in_dim(cos, pos_s, 1)  # [1, Dh/2]
    sin_p = jax.lax.dynamic_slice_in_dim(sin, pos_s, 1)
    x = p["embed"][token[0]]                             # [D]

    def layer(x, ws):
        (wq, wk, wv, wo, wg, wu, wd, na, nm, kc, vc) = ws
        h_in = rmsnorm(x, na)
        q = (h_in @ wq).reshape(H, 1, Dh)                # [H, 1, Dh]
        k = (h_in @ wk).reshape(H, 1, Dh)
        v = (h_in @ wv).reshape(H, 1, Dh)
        q = _apply_rope(q, cos_p[None], sin_p[None])[:, 0, :]   # [H, Dh]
        k = _apply_rope(k, cos_p[None], sin_p[None])
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos_s, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos_s, 0))
        o = decode_attention(q, kc, vc, pos_s + 1)       # [H, Dh]
        x = x + o.reshape(H * Dh) @ wo
        x = x + _mlp(rmsnorm(x, nm), wg, wu, wd)
        return x, (kc, vc)

    xs = (p["wq"], p["wk"], p["wv"], p["wo"], p["w_gate"], p["w_up"],
          p["w_down"], p["norm_attn"], p["norm_mlp"], k_cache, v_cache)
    x, (k_new, v_new) = jax.lax.scan(layer, x, xs)
    logits = rmsnorm(x, p["norm_f"]) @ p["unembed"]
    return logits, k_new, v_new


def generate_greedy(cfg: ModelConfig, params, prompt_tokens, n_output):
    """Reference autoregressive generation (prefill + greedy decode loop).

    Used by the build-time tests and to emit the golden trace the Rust
    integration test replays. Returns the list of generated token ids.
    """
    flat = flatten_params(cfg, params)
    s = cfg.max_seq
    toks = jnp.zeros((s,), jnp.int32).at[: len(prompt_tokens)].set(
        jnp.array(prompt_tokens, jnp.int32))
    logits, kc, vc = jax.jit(
        lambda f, t: prefill(cfg, f, t))(flat, toks)
    n_in = len(prompt_tokens)
    out = []
    tok = int(jnp.argmax(logits[n_in - 1]))
    dec = jax.jit(lambda f, t, p, k, v: decode(cfg, f, t, p, k, v))
    for i in range(n_output):
        out.append(tok)
        if n_in + i >= s:
            break
        lg, kc, vc = dec(flat, jnp.array([tok], jnp.int32),
                         jnp.array([n_in + i], jnp.int32), kc, vc)
        tok = int(jnp.argmax(lg))
    return out
